//! Design-choice ablations beyond the paper's tables (DESIGN.md §6):
//!
//! 1. **Formulation**: phase-decomposed unified vs literal per-element
//!    Algorithm 2 vs grouped (HICSS'23) on odd-output layers — isolates
//!    the cost of runtime sub-kernel selection and the prior work's
//!    extra-element waste.
//! 2. **GEMM route** (§5 discussion): im2col conventional vs segregated
//!    GEMM vs direct unified — quantifies the re-arrangement overhead
//!    the paper predicts.
//! 3. **Zero-skip baseline**: how much of the win a branchy CPU
//!    baseline recovers (honesty check on the conventional baseline).
//! 4. **Dilated convolution** (§5 future work): naive vs
//!    segregated-input.
//! 5. **Lane scaling**: unified-kernel thread scaling.
//! 6. **Plan/execute** (DESIGN.md §Plan-Execute): ahead-of-time
//!    [`ConvTransposePlan`] + warm scratch arena vs the per-call paths
//!    that re-segregate, re-plan and re-allocate on every invocation.
//! 7. **Autotuning** (DESIGN.md §Autotuning): hand-picked execution
//!    strategies vs the tuner's per-layer winners.
//! 8. **Direct vs phase-GEMM** (DESIGN.md §GEMM-Execution): the
//!    planned correlation path against the packed phase-GEMM engine,
//!    per Table-4 DC-GAN layer, with achieved GFLOP/s — locating the
//!    crossover on large-`Cout` layers.  Reports the active microkernel
//!    ISA per row and, on SIMD hosts, a forced-scalar GEMM column
//!    (DESIGN.md §SIMD-Dispatch).
//! 9. **Fused batch vs per-latent** (DESIGN.md §Batched-Execution):
//!    the fused batched GEMM lane against a per-latent loop of the
//!    same engine, per Table-4 layer and batch size — how the
//!    packed-panel reuse scales with `N`.
//! 10. **Planned vs unplanned backward** (DESIGN.md
//!    §Backward-Execution): the plan's batched backward lanes against a
//!    per-image loop of the one-shot unified gradients, per Table-4
//!    layer and batch size — plus a `training_step` column timing the
//!    whole forward→loss→backward→SGD step.  [`backward_snapshot_json`]
//!    serializes this ablation into the committed `BENCH_*.json`
//!    snapshots.
//! 11. **Span-recorder overhead** (DESIGN.md §Observability): the
//!    planned forward with tracing off vs on — prices the two clock
//!    reads + ring push per span against the <1% budget.
//! 12. **Reduced precision** (DESIGN.md §Reduced-Precision): the
//!    planned serial phase-GEMM engine at f32/f16/bf16/int8 packed-B
//!    storage, per Table-4 DC-GAN layer — latency, max-abs drift vs
//!    the layer's f32 lane, and packed-operand bytes in one row.
//!    [`precision_json`] serializes this ablation into the
//!    `BENCH_*.json` snapshots.
//! 13. **Fused vs separate epilogue** (DESIGN.md §Fused-Epilogue): the
//!    phase-GEMM engine storing bias+activation in-register straight
//!    into the strided output vs the historic slab → scatter →
//!    separate bias/activation passes, per Table-4 layer × batch size,
//!    with GF/s and the analytic epilogue bytes each route moves.
//!    [`fusion_json`] serializes this ablation into the `fusion`
//!    section of the `BENCH_*.json` snapshots.

use std::collections::BTreeMap;

use crate::conv::backward::{grad_input_unified, grad_kernel_unified};
use crate::conv::gemm;
use crate::conv::parallel::{run, Algorithm, Lane};
use crate::conv::plan::{ConvTransposePlan, Scratch};
use crate::conv::quant::Precision;
use crate::conv::simd::Isa;
use crate::conv::{conventional, dilated, flops, im2col, unified, ConvTransposeParams};
use crate::models::zoo::GanModel;
use crate::models::{Generator, TrainStep};
use crate::obs::{registry, trace};
use crate::tensor::{Feature, FeatureBatch, Kernel};
use crate::tune::{ExecStrategy, MeasureBudget, ParAxis, Tuner, WallClockMeasurer};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timing;

use super::{report, BenchConfig};

/// A named measurement: median seconds plus the raw samples, so the
/// table can report the shared mean/best/p50/p95 vocabulary
/// ([`report::Latency`]), and optionally the analytic MAC count of the
/// measured operation so the table can report achieved GFLOP/s
/// (`conv::flops` → [`report::gflops`]).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub seconds: f64,
    pub samples: Vec<f64>,
    /// Analytic multiply-accumulates per iteration (`None` = no model,
    /// GFLOP/s column prints "-").
    pub macs: Option<u64>,
}

impl Entry {
    /// Measure `f` under `cfg` and keep the samples.
    pub fn measure<T>(name: impl Into<String>, cfg: &BenchConfig, f: impl FnMut() -> T) -> Entry {
        let m = timing::measure(cfg.warmup, cfg.iters.max(2), f);
        Entry {
            name: name.into(),
            seconds: m.median(),
            samples: m.samples,
            macs: None,
        }
    }

    /// Attach the analytic MAC count of the measured operation.
    pub fn with_macs(mut self, macs: u64) -> Entry {
        self.macs = Some(macs);
        self
    }
}

/// Ablation 1: formulation comparison on an odd-output configuration
/// (input 112×112×8, kernel 5×5, P=2 → 223×223 output, odd).
pub fn formulation(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF0);
    let x = Feature::random(112, 112, 8, &mut rng);
    let k = Kernel::random(5, 8, 4, &mut rng);
    let p = 2;
    let params = ConvTransposeParams::new(112, 5, p, 8, 4);
    vec![
        Entry::measure("conventional (Alg.1)", cfg, || {
            run(Algorithm::Conventional, Lane::Serial, &x, &k, p)
        })
        .with_macs(flops::conventional(&params)),
        Entry::measure("grouped (HICSS'23, extra elements)", cfg, || {
            run(Algorithm::Grouped, Lane::Serial, &x, &k, p)
        })
        .with_macs(flops::grouped(&params)),
        Entry::measure("unified per-element (Alg.2 literal)", cfg, || {
            run(Algorithm::UnifiedPerElement, Lane::Serial, &x, &k, p)
        })
        .with_macs(flops::unified(&params)),
        Entry::measure("unified phase-decomposed (hot path)", cfg, || {
            run(Algorithm::Unified, Lane::Serial, &x, &k, p)
        })
        .with_macs(flops::unified(&params)),
    ]
}

/// Ablation 2: GEMM routes (§5).
pub fn gemm_routes(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF1);
    let x = Feature::random(56, 56, 16, &mut rng);
    let k = Kernel::random(4, 16, 8, &mut rng);
    let p = 2;
    // GFLOP/s denominators: the im2col route's GEMM is dimensioned for
    // the full upsampled map (conventional MACs, zeros included); both
    // segregated routes perform the unified count.
    let params = ConvTransposeParams::new(56, 4, p, 16, 8);
    vec![
        Entry::measure("im2col conventional GEMM", cfg, || {
            im2col::transpose_conv(&x, &k, p)
        })
        .with_macs(flops::conventional(&params)),
        Entry::measure("segregated GEMM + rearrange (§5)", cfg, || {
            im2col::transpose_conv_segregated_gemm(&x, &k, p).0
        })
        .with_macs(flops::unified(&params)),
        Entry::measure("unified direct (no GEMM)", cfg, || {
            unified::transpose_conv(&x, &k, p)
        })
        .with_macs(flops::unified(&params)),
    ]
}

/// Ablation 3: zero-skip branchy baseline vs dense vs unified.
pub fn zero_skip(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF2);
    let x = Feature::random(112, 112, 3, &mut rng);
    let k = Kernel::random(5, 3, 1, &mut rng);
    let p = 2;
    let params = ConvTransposeParams::new(112, 5, p, 3, 1);
    vec![
        Entry::measure("conventional dense", cfg, || {
            conventional::transpose_conv(&x, &k, p)
        })
        .with_macs(flops::conventional(&params)),
        Entry::measure("conventional + zero-skip branch", cfg, || {
            conventional::transpose_conv_zeroskip(&x, &k, p)
        })
        .with_macs(flops::unified(&params)),
        Entry::measure("unified", cfg, || unified::transpose_conv(&x, &k, p))
            .with_macs(flops::unified(&params)),
    ]
}

/// Ablation 4: dilated conv, naive vs segregated-input (§5 future work).
pub fn dilated_routes(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF3);
    let x = Feature::random(128, 128, 8, &mut rng);
    let k = Kernel::random(3, 8, 8, &mut rng);
    vec![
        Entry::measure("dilated naive (upsampled kernel)", cfg, || {
            dilated::dilated_conv_naive(&x, &k)
        }),
        Entry::measure("dilated segregated-input (§5)", cfg, || {
            dilated::dilated_conv_segregated(&x, &k)
        }),
    ]
}

/// Ablation 5: parallel-lane scaling of the unified kernel.
pub fn lane_scaling(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF4);
    let x = Feature::random(112, 112, 8, &mut rng);
    let k = Kernel::random(4, 8, 8, &mut rng);
    let macs = flops::unified(&ConvTransposeParams::new(112, 4, 2, 8, 8));
    let mut out = vec![Entry::measure("serial", cfg, || {
        run(Algorithm::Unified, Lane::Serial, &x, &k, 2)
    })
    .with_macs(macs)];
    for w in [2, 4, cfg.workers.max(2)] {
        out.push(
            Entry::measure(format!("parallel({w})"), cfg, || {
                run(Algorithm::Unified, Lane::Parallel(w), &x, &k, 2)
            })
            .with_macs(macs),
        );
    }
    out
}

/// Ablation 6: plan/execute vs per-call planning over the Table-4
/// DC-GAN transpose-conv layer set (serial lane, whole stack per
/// iteration).
///
/// Rows, in increasing amounts of ahead-of-time work:
/// 1. the naive caller — [`unified::transpose_conv`] segregates the
///    kernel, recomputes phase geometry and allocates every buffer per
///    call;
/// 2. pre-segregated weights ([`unified::transpose_conv_seg`]) — still
///    per-call geometry + allocations;
/// 3. the planned path — geometry frozen in a [`ConvTransposePlan`],
///    slabs/phases in a warm [`Scratch`] arena, output buffers reused:
///    zero steady-state allocations.
pub fn planning(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF5);
    let layers: Vec<(Feature, Kernel, ConvTransposePlan)> = GanModel::DcGan
        .layers()
        .iter()
        .map(|spec| {
            let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
            let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            let plan = ConvTransposePlan::new(spec.params(), &k);
            (x, k, plan)
        })
        .collect();
    let stack_macs: u64 = layers
        .iter()
        .map(|(_, _, plan)| flops::unified(plan.params()))
        .sum();
    let unplanned = Entry::measure("unplanned (segregate + plan per call)", cfg, || {
        for (x, k, plan) in &layers {
            timing::consume(unified::transpose_conv(x, k, plan.params().padding));
        }
    })
    .with_macs(stack_macs);
    let preseg = Entry::measure("unplanned (pre-segregated weights)", cfg, || {
        for (x, _, plan) in &layers {
            timing::consume(unified::transpose_conv_seg(x, plan.seg(), plan.params().padding));
        }
    })
    .with_macs(stack_macs);
    let mut scratch = Scratch::for_plans(layers.iter().map(|(_, _, plan)| plan));
    let mut outs: Vec<Feature> = layers.iter().map(|(_, _, plan)| plan.new_output()).collect();
    let planned = Entry::measure("planned (AOT plan + scratch arena)", cfg, || {
        for ((x, _, plan), out) in layers.iter().zip(&mut outs) {
            plan.run(x, &mut scratch, out);
        }
        outs[0].data[0]
    })
    .with_macs(stack_macs);
    vec![unplanned, preseg, planned]
}

/// Ablation 7 (DESIGN.md §Autotuning): hand-picked execution
/// strategies vs the autotuner's per-layer winners over the Table-4
/// DC-GAN layer set — the "tuned" column for the design ablations.
/// "Hand-picked" is what every caller did before the tuner existed:
/// the serial phase decomposition, or one global parallel lane at the
/// bench's worker count.
pub fn autotune(cfg: &BenchConfig) -> Vec<Entry> {
    let mut rng = Rng::seeded(0xF6);
    let layers: Vec<(Feature, ConvTransposePlan)> = GanModel::DcGan
        .layers()
        .iter()
        .map(|spec| {
            let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
            let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            (x, ConvTransposePlan::new(spec.params(), &k))
        })
        .collect();
    let stack_macs: u64 = layers
        .iter()
        .map(|(_, plan)| flops::unified(plan.params()))
        .sum();
    let mut scratch = Scratch::for_plans(layers.iter().map(|(_, plan)| plan));
    let mut outs: Vec<Feature> = layers.iter().map(|(_, plan)| plan.new_output()).collect();
    let serial = Entry::measure("hand-picked: phase/serial (whole stack)", cfg, || {
        for ((x, plan), out) in layers.iter().zip(&mut outs) {
            plan.run(x, &mut scratch, out);
        }
        outs[0].data[0]
    })
    .with_macs(stack_macs);
    let par = ExecStrategy::parallel(cfg.workers.max(2), ParAxis::PhaseRows);
    let hand_par = Entry::measure(format!("hand-picked: {} (whole stack)", par.name()), cfg, || {
        for ((x, plan), out) in layers.iter().zip(&mut outs) {
            plan.run_with(&par, x, &mut scratch, out);
        }
        outs[0].data[0]
    })
    .with_macs(stack_macs);
    let tuner = Tuner::new(cfg.workers.max(2)).with_budget(MeasureBudget {
        warmup: cfg.warmup,
        min_time_s: 0.0,
        max_iters: cfg.iters.max(1),
    });
    let mut measurer = WallClockMeasurer::new(tuner.budget);
    let winners: Vec<ExecStrategy> = layers
        .iter()
        .map(|(_, plan)| tuner.tune_layer(plan, &mut measurer).strategy)
        .collect();
    let tuned = Entry::measure("autotuned per layer", cfg, || {
        for (((x, plan), out), s) in layers.iter().zip(&mut outs).zip(&winners) {
            plan.run_with(s, x, &mut scratch, out);
        }
        outs[0].data[0]
    })
    .with_macs(stack_macs);
    vec![serial, hand_par, tuned]
}

/// Ablation 8 (DESIGN.md §GEMM-Execution): one row per Table-4 DC-GAN
/// layer, planned **direct** serial execution next to the planned
/// **phase-GEMM** serial engine — the direct-vs-GEMM column.  The
/// formulations share the analytic MAC count, so the GFLOP/s columns
/// expose the crossover in hardware terms: the packed GEMM wins where
/// `Cout` fills the register tile (the wide early layers) and loses to
/// the rank-1 correlation on the `Cout = 3` RGB head.
pub struct GemmCrossRow {
    pub layer: String,
    pub direct: Entry,
    /// Phase-GEMM through the host's active microkernel lane.
    pub gemm: Entry,
    /// The microkernel lane the `gemm` column ran (DESIGN.md
    /// §SIMD-Dispatch).
    pub isa: Isa,
    /// Phase-GEMM forced onto the portable scalar microkernel — the
    /// SIMD-vs-scalar A/B.  `None` on scalar hosts, where it would
    /// duplicate `gemm`.
    pub gemm_scalar: Option<Entry>,
    pub macs: u64,
}

/// Measure the direct-vs-GEMM crossover per layer of `model`
/// (the printed ablation uses DC-GAN; tests use the lighter GP-GAN).
pub fn gemm_crossover(model: GanModel, cfg: &BenchConfig) -> Vec<GemmCrossRow> {
    let mut rng = Rng::seeded(0xF7);
    model
        .layers()
        .iter()
        .map(|spec| {
            let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
            let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            let plan = ConvTransposePlan::new(spec.params(), &k);
            let macs = flops::unified(plan.params());
            let mut scratch = Scratch::for_plan(&plan);
            let mut out = plan.new_output();
            let direct = Entry::measure("direct", cfg, || {
                plan.run(&x, &mut scratch, &mut out);
                out.data[0]
            })
            .with_macs(macs);
            let gemm = Entry::measure("phase-gemm", cfg, || {
                plan.run_gemm(&x, &mut scratch, &mut out);
                out.data[0]
            })
            .with_macs(macs);
            let isa = Isa::active();
            let gemm_scalar = (isa != Isa::Scalar).then(|| {
                let pinned = ExecStrategy::serial_gemm().with_isa(Isa::Scalar);
                Entry::measure("phase-gemm/scalar", cfg, || {
                    plan.run_with(&pinned, &x, &mut scratch, &mut out);
                    out.data[0]
                })
                .with_macs(macs)
            });
            GemmCrossRow {
                layer: spec.describe(),
                direct,
                gemm,
                isa,
                gemm_scalar,
                macs,
            }
        })
        .collect()
}

/// Print the ablation-8 table (direct vs GEMM, latency + GFLOP/s).
pub fn print_gemm_crossover(rows: &[GemmCrossRow]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                timing::fmt_duration(r.direct.seconds),
                timing::fmt_duration(r.gemm.seconds),
                r.isa.name().into(),
                report::gflops_cell(r.macs, r.direct.seconds),
                report::gflops_cell(r.macs, r.gemm.seconds),
                report::speedup(r.direct.seconds / r.gemm.seconds),
                // SIMD-vs-scalar microkernel A/B: how much of the GEMM
                // column the vector lane is worth ("-" on scalar hosts).
                r.gemm_scalar
                    .as_ref()
                    .map(|e| report::speedup(e.seconds / r.gemm.seconds))
                    .unwrap_or_else(|| "-".into()),
            ]
        })
        .collect();
    report::print_table(
        "Ablation 8 — direct vs phase-GEMM (planned serial, Table-4 DC-GAN layers)",
        &[
            "layer",
            "direct",
            "phase-gemm",
            "isa",
            "direct GF/s",
            "gemm GF/s",
            "gemm speedup",
            "vs scalar ukernel",
        ],
        &table,
    );
}

/// Ablation 9 (DESIGN.md §Batched-Execution): one row per
/// `(Table-4 layer, batch size)` — the planned serial phase-GEMM
/// engine run as a per-latent loop vs the fused batched lane
/// (`run_gemm_batch`, one stacked GEMM per phase for the whole batch).
/// Same engine, same packed operands, identical MACs per batch — the
/// speedup column isolates what streaming each packed B panel once
/// per batch (instead of once per latent) buys as `N` grows.
pub struct BatchFusionRow {
    pub layer: String,
    pub batch: usize,
    /// Per-latent loop of `run_gemm` over the batch.
    pub per_latent: Entry,
    /// Fused `run_gemm_batch` over the same batch.
    pub fused: Entry,
    /// Analytic MACs per batch (shared by both lanes).
    pub macs: u64,
}

/// Measure the fused-batch vs per-latent crossover per layer of
/// `model` at each batch size (the printed ablation uses DC-GAN and
/// batches 1/4/8; tests use the lighter GP-GAN).
pub fn batch_fusion(model: GanModel, cfg: &BenchConfig, batches: &[usize]) -> Vec<BatchFusionRow> {
    let mut rng = Rng::seeded(0xF8);
    let mut rows = Vec::new();
    for spec in model.layers() {
        let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
        let plan = ConvTransposePlan::new(spec.params(), &k);
        for &n in batches {
            let n = n.max(1);
            let xb = FeatureBatch::random(n, spec.n_in, spec.n_in, spec.cin, &mut rng);
            let xs: Vec<Feature> = (0..n).map(|i| xb.feature(i)).collect();
            let macs = n as u64 * flops::unified(plan.params());
            let mut scratch = Scratch::with_floats(
                plan.scratch_floats_gemm_batch(n).max(plan.scratch_floats()),
            );
            let mut one = plan.new_output();
            let per_latent = Entry::measure(format!("per-latent b{n}"), cfg, || {
                for x in &xs {
                    plan.run_gemm(x, &mut scratch, &mut one);
                }
                one.data[0]
            })
            .with_macs(macs);
            let mut outb = plan.new_batch_output(n);
            let fused = Entry::measure(format!("fused b{n}"), cfg, || {
                plan.run_gemm_batch(&xb, &mut scratch, &mut outb);
                outb.data[0]
            })
            .with_macs(macs);
            rows.push(BatchFusionRow {
                layer: spec.describe(),
                batch: n,
                per_latent,
                fused,
                macs,
            });
        }
    }
    rows
}

/// Print the ablation-9 table (fused batch vs per-latent, per layer ×
/// batch size).
pub fn print_batch_fusion(rows: &[BatchFusionRow]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                r.batch.to_string(),
                timing::fmt_duration(r.per_latent.seconds),
                timing::fmt_duration(r.fused.seconds),
                report::gflops_cell(r.macs, r.per_latent.seconds),
                report::gflops_cell(r.macs, r.fused.seconds),
                report::speedup(r.per_latent.seconds / r.fused.seconds),
            ]
        })
        .collect();
    report::print_table(
        "Ablation 9 — fused batch vs per-latent (planned phase-GEMM, Table-4 DC-GAN layers)",
        &[
            "layer",
            "batch",
            "per-latent",
            "fused",
            "per-latent GF/s",
            "fused GF/s",
            "fused speedup",
        ],
        &table,
    );
}

/// Ablation 10 (DESIGN.md §Backward-Execution): one row per
/// `(Table-4 layer, batch size)` — a per-image loop of the one-shot
/// unified gradients (re-deriving phase geometry and allocating every
/// buffer per image, the pre-plan baseline) against the plan's batched
/// backward lanes (frozen flipped sub-kernels, one warm arena, the
/// weight-grad accumulated across the batch by the phase GEMM's
/// `C +=`).  Data-grad and weight-grad each perform the unified MAC
/// count, so `macs = 2·N·unified`.
pub struct BackwardRow {
    pub layer: String,
    pub batch: usize,
    /// Per-image `grad_input_unified` + `grad_kernel_unified` loop.
    pub unplanned: Entry,
    /// `run_backward_data_batch` + `run_backward_weights_batch`.
    pub planned: Entry,
    /// Analytic MACs per batch (shared by both lanes).
    pub macs: u64,
}

/// Measure planned vs unplanned backward per layer of `model` at each
/// batch size (the printed ablation uses DC-GAN and batches 1/4/8;
/// tests use the lighter GP-GAN).
pub fn backward_planning(
    model: GanModel,
    cfg: &BenchConfig,
    batches: &[usize],
) -> Vec<BackwardRow> {
    let mut rng = Rng::seeded(0xF9);
    let mut rows = Vec::new();
    for spec in model.layers() {
        let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
        let plan = ConvTransposePlan::new(spec.params(), &k);
        let out = spec.n_out();
        for &n in batches {
            let n = n.max(1);
            let xb = FeatureBatch::random(n, spec.n_in, spec.n_in, spec.cin, &mut rng);
            let dyb = FeatureBatch::random(n, out, out, spec.cout, &mut rng);
            let xs: Vec<Feature> = (0..n).map(|i| xb.feature(i)).collect();
            let dys: Vec<Feature> = (0..n).map(|i| dyb.feature(i)).collect();
            let macs = 2 * n as u64 * flops::unified(plan.params());
            let unplanned = Entry::measure(format!("unplanned b{n}"), cfg, || {
                let mut acc = 0.0f32;
                for (x, dy) in xs.iter().zip(&dys) {
                    let dx = grad_input_unified(dy, &k, spec.n_in, spec.padding);
                    let dk = grad_kernel_unified(x, dy, spec.ksize, spec.padding);
                    acc += dx.data[0] + dk.data[0];
                }
                acc
            })
            .with_macs(macs);
            let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
            let mut dxb = FeatureBatch::zeros(n, spec.n_in, spec.n_in, spec.cin);
            let mut dk = plan.new_kernel_grad();
            let planned = Entry::measure(format!("planned b{n}"), cfg, || {
                plan.run_backward_data_batch(&dyb, &mut scratch, &mut dxb);
                plan.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut dk);
                dxb.image(0)[0] + dk.data[0]
            })
            .with_macs(macs);
            rows.push(BackwardRow {
                layer: spec.describe(),
                batch: n,
                unplanned,
                planned,
                macs,
            });
        }
    }
    rows
}

/// Print the ablation-10 table (planned vs unplanned backward, per
/// layer × batch size).
pub fn print_backward_planning(rows: &[BackwardRow]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                r.batch.to_string(),
                timing::fmt_duration(r.unplanned.seconds),
                timing::fmt_duration(r.planned.seconds),
                report::gflops_cell(r.macs, r.unplanned.seconds),
                report::gflops_cell(r.macs, r.planned.seconds),
                report::speedup(r.unplanned.seconds / r.planned.seconds),
            ]
        })
        .collect();
    report::print_table(
        "Ablation 10 — planned vs unplanned backward (Table-4 DC-GAN layers)",
        &[
            "layer",
            "batch",
            "unplanned",
            "planned",
            "unplanned GF/s",
            "planned GF/s",
            "planned speedup",
        ],
        &table,
    );
}

/// Ablation 12 (DESIGN.md §Reduced-Precision): the planned serial
/// phase-GEMM engine at every storage precision, per Table-4 layer.
/// Latency, max-abs drift against the layer's own f32 phase-GEMM
/// output, and the packed-operand bytes at that precision land in one
/// row — speed, accuracy, and footprint of the same lane, side by
/// side.
pub struct PrecisionRow {
    pub layer: String,
    pub precision: Precision,
    pub entry: Entry,
    /// Max |Δ| vs the f32 phase-GEMM output of the same layer and
    /// input (0 for the f32 row itself).
    pub max_abs: f64,
    /// Plan-resident packed-B bytes at this precision
    /// (`ConvTransposePlan::packed_operand_bytes`).
    pub packed_bytes: usize,
    pub macs: u64,
}

/// Measure the per-precision phase-GEMM lanes per layer of `model`
/// (the printed ablation uses DC-GAN; tests use the lighter GP-GAN).
pub fn precision_lanes(model: GanModel, cfg: &BenchConfig) -> Vec<PrecisionRow> {
    let mut rng = Rng::seeded(0xFB);
    let mut rows = Vec::new();
    for spec in model.layers() {
        let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
        let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
        let plan = ConvTransposePlan::new(spec.params(), &k);
        let macs = flops::unified(plan.params());
        let mut scratch = Scratch::for_plan(&plan);
        let mut out = plan.new_output();
        let mut reference = plan.new_output();
        plan.run_gemm(&x, &mut scratch, &mut reference);
        for p in Precision::ALL {
            let pinned = ExecStrategy::serial_gemm().with_precision(p);
            let entry = Entry::measure(format!("phase-gemm/{}", p.name()), cfg, || {
                plan.run_with(&pinned, &x, &mut scratch, &mut out);
                out.data[0]
            })
            .with_macs(macs);
            let max_abs = f64::from(crate::tensor::ops::max_abs_diff(&reference, &out));
            rows.push(PrecisionRow {
                layer: spec.describe(),
                precision: p,
                entry,
                max_abs,
                packed_bytes: plan.packed_operand_bytes(p),
                macs,
            });
        }
    }
    rows
}

/// Print the ablation-12 table (per-precision phase-GEMM lanes).
pub fn print_precision_lanes(rows: &[PrecisionRow]) {
    let mut f32_seconds = 0.0;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            if r.precision == Precision::F32 {
                f32_seconds = r.entry.seconds;
            }
            vec![
                r.layer.clone(),
                r.precision.name().into(),
                timing::fmt_duration(r.entry.seconds),
                report::gflops_cell(r.macs, r.entry.seconds),
                report::speedup(f32_seconds / r.entry.seconds),
                format!("{:.3e}", r.max_abs),
                r.packed_bytes.to_string(),
            ]
        })
        .collect();
    report::print_table(
        "Ablation 12 — phase-GEMM storage precision (planned serial, Table-4 DC-GAN layers)",
        &[
            "layer",
            "precision",
            "median",
            "GF/s",
            "vs f32 lane",
            "max-abs vs f32",
            "packed B",
        ],
        &table,
    );
}

/// The `precision` section of the `BENCH_*.json` snapshot: ablation 12
/// serialized — one object per (layer, precision) with latency, drift
/// and operand footprint, so the f16 2× / int8 4× packed-operand
/// claims and the drift budgets are machine-checkable.
pub fn precision_json(model: GanModel, cfg: &BenchConfig) -> Json {
    let rows = precision_lanes(model, cfg)
        .into_iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("layer".to_string(), Json::Str(r.layer));
            o.insert(
                "precision".to_string(),
                Json::Str(r.precision.name().to_string()),
            );
            o.insert("seconds".to_string(), Json::Num(r.entry.seconds));
            o.insert("max_abs_vs_f32".to_string(), Json::Num(r.max_abs));
            o.insert(
                "packed_operand_bytes".to_string(),
                Json::Num(r.packed_bytes as f64),
            );
            Json::Obj(o)
        })
        .collect();
    Json::Arr(rows)
}

/// Ablation 13 (DESIGN.md §Fused-Epilogue): one row per
/// `(Table-4 layer, batch size)` — the planned phase-GEMM engine with
/// the layer epilogue (per-channel bias + ReLU) applied the historic
/// way (phase slab → `scatter_rows` → separate bias pass → separate
/// activation pass) vs fused in-register into the strided output
/// store.  Same packed operands, identical MACs — the delta is pure
/// memory traffic, so the row also carries the analytic epilogue
/// bytes of each route: the phases partition the output, so per
/// output float the separate route moves 7 floats (slab write, slab
/// read, scatter write, bias read+write, activation read+write) where
/// the fused route moves 1 (the single epilogue store).
pub struct EpilogueFusionRow {
    pub layer: String,
    pub batch: usize,
    /// Slab + scatter + separate bias/activation passes.
    pub separate: Entry,
    /// Bias+activation folded into the strided GEMM store.
    pub fused: Entry,
    /// Analytic output-side bytes of the separate route (7 floats per
    /// output element).
    pub separate_bytes: u64,
    /// Analytic output-side bytes of the fused route (1 float per
    /// output element).
    pub fused_bytes: u64,
    /// Analytic MACs per batch (shared by both routes).
    pub macs: u64,
}

/// Measure the fused-vs-separate epilogue per layer of `model` at each
/// batch size (the printed ablation uses DC-GAN and batches 1/4/8;
/// tests use the lighter GP-GAN).
pub fn epilogue_fusion(
    model: GanModel,
    cfg: &BenchConfig,
    batches: &[usize],
) -> Vec<EpilogueFusionRow> {
    let mut rng = Rng::seeded(0xFC);
    let sep = ExecStrategy::serial_gemm().fused();
    let fus = ExecStrategy::serial_gemm().fused().fused_epilogue();
    let mut rows = Vec::new();
    for spec in model.layers() {
        let k = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
        let plan = ConvTransposePlan::new(spec.params(), &k);
        let bias = Feature::random(1, 1, spec.cout, &mut rng).data;
        for &n in batches {
            let n = n.max(1);
            let xb = FeatureBatch::random(n, spec.n_in, spec.n_in, spec.cin, &mut rng);
            let macs = n as u64 * flops::unified(plan.params());
            // The separate route's arena covers the fused one (which
            // drops the phase region entirely).
            let mut scratch = Scratch::with_floats(plan.scratch_floats_for_batch(&sep, n));
            let mut outb = plan.new_batch_output(n);
            let epi = gemm::Epilogue {
                bias: Some(&bias[..]),
                act: gemm::Activation::Relu,
            };
            let separate = Entry::measure(format!("separate b{n}"), cfg, || {
                plan.run_batch_with_epilogue(&sep, &xb, &mut scratch, &mut outb, &epi);
                outb.data[0]
            })
            .with_macs(macs);
            let fused = Entry::measure(format!("fused b{n}"), cfg, || {
                plan.run_batch_with_epilogue(&fus, &xb, &mut scratch, &mut outb, &epi);
                outb.data[0]
            })
            .with_macs(macs);
            let out_floats = outb.data.len() as u64;
            rows.push(EpilogueFusionRow {
                layer: spec.describe(),
                batch: n,
                separate,
                fused,
                separate_bytes: 7 * 4 * out_floats,
                fused_bytes: 4 * out_floats,
                macs,
            });
        }
    }
    rows
}

/// Print the ablation-13 table (fused vs separate epilogue, per layer
/// × batch size).
pub fn print_epilogue_fusion(rows: &[EpilogueFusionRow]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                r.batch.to_string(),
                timing::fmt_duration(r.separate.seconds),
                timing::fmt_duration(r.fused.seconds),
                report::gflops_cell(r.macs, r.separate.seconds),
                report::gflops_cell(r.macs, r.fused.seconds),
                format!("{} → {}", r.separate_bytes, r.fused_bytes),
                report::speedup(r.separate.seconds / r.fused.seconds),
            ]
        })
        .collect();
    report::print_table(
        "Ablation 13 — fused vs separate epilogue (planned phase-GEMM, Table-4 DC-GAN layers)",
        &[
            "layer",
            "batch",
            "separate",
            "fused",
            "separate GF/s",
            "fused GF/s",
            "epilogue bytes",
            "fused speedup",
        ],
        &table,
    );
}

/// The `fusion` section of the `BENCH_*.json` snapshot: ablation 13
/// serialized — one object per (layer, batch) with both latencies, the
/// speedup, and the analytic epilogue bytes, so the retired memory
/// pass is machine-checkable.
pub fn fusion_json(model: GanModel, cfg: &BenchConfig, batches: &[usize]) -> Json {
    let rows = epilogue_fusion(model, cfg, batches)
        .into_iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("layer".to_string(), Json::Str(r.layer));
            o.insert("batch".to_string(), Json::Num(r.batch as f64));
            o.insert("separate_s".to_string(), Json::Num(r.separate.seconds));
            o.insert("fused_s".to_string(), Json::Num(r.fused.seconds));
            o.insert(
                "fused_speedup".to_string(),
                Json::Num(r.separate.seconds / r.fused.seconds),
            );
            o.insert(
                "separate_epilogue_bytes".to_string(),
                Json::Num(r.separate_bytes as f64),
            );
            o.insert(
                "fused_epilogue_bytes".to_string(),
                Json::Num(r.fused_bytes as f64),
            );
            o.insert("macs".to_string(), Json::Num(r.macs as f64));
            Json::Obj(o)
        })
        .collect();
    Json::Arr(rows)
}

/// The `training_step` bench column: a full forward→MSE→backward→SGD
/// step on the smallest Table-4 generator, direct vs phase-GEMM
/// backward data-grad lanes ([`TrainStep`]).
pub fn training_step(cfg: &BenchConfig) -> Vec<Entry> {
    let model = GanModel::smallest();
    let mut rng = Rng::seeded(0xFA);
    let gen = Generator::random(model, &mut rng);
    let mut gemm_gen = gen.clone();
    let pins: Vec<ExecStrategy> = gemm_gen
        .layers
        .iter()
        .map(|_| ExecStrategy::serial_gemm())
        .collect();
    gemm_gen.set_backward_strategies(&pins);
    // A tiny learning rate keeps the weights (and so the work) stable
    // across the timed repetitions.
    let mut direct_ts = TrainStep::new(gen, &mut rng, 1e-4);
    let direct = Entry::measure(
        format!("training step ({}, direct backward)", model.name()),
        cfg,
        || direct_ts.step(),
    );
    let mut gemm_ts = TrainStep::new(gemm_gen, &mut rng, 1e-4);
    let gemm = Entry::measure(
        format!("training step ({}, phase-GEMM backward)", model.name()),
        cfg,
        || gemm_ts.step(),
    );
    vec![direct, gemm]
}

/// Ablation 11: span-recorder overhead A/B (ISSUE 8 acceptance) — the
/// planned serial forward with tracing disabled vs enabled.  The
/// disabled row is the baseline the <1% budget is judged against; the
/// enabled row prices the two clock reads + ring push per span.
pub fn tracing_overhead(cfg: &BenchConfig) -> Vec<Entry> {
    let model = GanModel::smallest();
    let mut rng = Rng::seeded(0xB0);
    let gen = Generator::random(model, &mut rng);
    let mut scratch = gen.scratch();
    let z: Vec<f32> = (0..gen.model.z_dim()).map(|_| rng.normal_f32()).collect();
    let was_enabled = trace::enabled();
    trace::disable();
    let off = Entry::measure(
        format!("planned forward ({}, tracing off)", model.name()),
        cfg,
        || gen.forward_with(&z, Algorithm::Unified, Lane::Serial, &mut scratch),
    );
    trace::enable();
    let on = Entry::measure(
        format!("planned forward ({}, tracing on)", model.name()),
        cfg,
        || gen.forward_with(&z, Algorithm::Unified, Lane::Serial, &mut scratch),
    );
    if !was_enabled {
        trace::disable();
        trace::clear();
    }
    vec![off, on]
}

/// The `observability` section of the `BENCH_*.json` snapshot: a traced
/// forward of `model` (DC-GAN in the CLI) rolled up per (name, lane),
/// the process-wide registry snapshot, and the ablation-11 overhead A/B
/// — per-phase attribution in machine-readable form, not just
/// end-to-end wall clock.
pub fn observability_json(model: GanModel, cfg: &BenchConfig) -> Json {
    let overhead = tracing_overhead(cfg);
    let mut rng = Rng::seeded(0xB1);
    let gen = Generator::random(model, &mut rng);
    let mut scratch = gen.scratch();
    let z: Vec<f32> = (0..gen.model.z_dim()).map(|_| rng.normal_f32()).collect();
    let was_enabled = trace::enabled();
    trace::enable();
    trace::clear();
    let _ = gen.forward_with(&z, Algorithm::Unified, Lane::Serial, &mut scratch);
    if !was_enabled {
        trace::disable();
    }
    let spans = trace::drain();
    let overhead_objs = overhead
        .iter()
        .map(|e| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(e.name.clone()));
            o.insert("seconds".to_string(), Json::Num(e.seconds));
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("trace_rollup".to_string(), trace::rollup_json(&spans));
    doc.insert("registry".to_string(), registry::global().json_snapshot());
    doc.insert("tracing_overhead".to_string(), Json::Arr(overhead_objs));
    Json::Obj(doc)
}

/// Serialize ablation 10 plus the `training_step` column into the
/// `BENCH_*.json` snapshot document (what `ukstc ablation --json PATH`
/// writes): stable key order, seconds + speedups, no derived columns
/// the reader can't recompute.
pub fn backward_snapshot_json(rows: &[BackwardRow], train: &[Entry]) -> Json {
    let row_objs = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("layer".to_string(), Json::Str(r.layer.clone()));
            o.insert("batch".to_string(), Json::Num(r.batch as f64));
            o.insert("unplanned_s".to_string(), Json::Num(r.unplanned.seconds));
            o.insert("planned_s".to_string(), Json::Num(r.planned.seconds));
            o.insert(
                "planned_speedup".to_string(),
                Json::Num(r.unplanned.seconds / r.planned.seconds),
            );
            o.insert("macs".to_string(), Json::Num(r.macs as f64));
            Json::Obj(o)
        })
        .collect();
    let train_objs = train
        .iter()
        .map(|e| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(e.name.clone()));
            o.insert("seconds".to_string(), Json::Num(e.seconds));
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("ablation10_backward".to_string(), Json::Arr(row_objs));
    doc.insert("training_step".to_string(), Json::Arr(train_objs));
    Json::Obj(doc)
}

/// Print one ablation block: median plus the shared mean/best/p50/p95
/// latency vocabulary, achieved GFLOP/s where an analytic MAC model
/// exists, and ratios relative to the first entry.
pub fn print_entries(title: &str, entries: &[Entry]) {
    let base = entries[0].seconds;
    let rows: Vec<Vec<String>> = entries
        .iter()
        .map(|e| {
            let mut row = vec![e.name.clone(), timing::fmt_duration(e.seconds)];
            row.extend(report::Latency::of(&e.samples).cells());
            row.push(
                e.macs
                    .map(|m| report::gflops_cell(m, e.seconds))
                    .unwrap_or_else(|| "-".into()),
            );
            row.push(report::speedup(base / e.seconds));
            row
        })
        .collect();
    report::print_table(
        title,
        &[
            "variant",
            "median",
            report::Latency::HEADERS[0],
            report::Latency::HEADERS[1],
            report::Latency::HEADERS[2],
            report::Latency::HEADERS[3],
            "GFLOP/s",
            "speedup vs first",
        ],
        &rows,
    );
}

/// Run and print every ablation.
pub fn run_all(cfg: &BenchConfig) {
    print_entries("Ablation 1 — formulation (odd 223×223 output)", &formulation(cfg));
    print_entries("Ablation 2 — GEMM routes (§5 discussion)", &gemm_routes(cfg));
    print_entries("Ablation 3 — zero-skip baseline honesty check", &zero_skip(cfg));
    print_entries("Ablation 4 — dilated conv (§5 future work)", &dilated_routes(cfg));
    print_entries("Ablation 5 — unified kernel lane scaling", &lane_scaling(cfg));
    print_entries(
        "Ablation 6 — plan/execute vs per-call (Table-4 DC-GAN layer set)",
        &planning(cfg),
    );
    print_entries(
        "Ablation 7 — hand-picked vs autotuned (Table-4 DC-GAN layer set)",
        &autotune(cfg),
    );
    print_gemm_crossover(&gemm_crossover(GanModel::DcGan, cfg));
    print_batch_fusion(&batch_fusion(GanModel::DcGan, cfg, &[1, 4, 8]));
    print_backward_planning(&backward_planning(GanModel::DcGan, cfg, &[1, 4, 8]));
    print_entries(
        "Training step — direct vs phase-GEMM backward (smallest Table-4 model)",
        &training_step(cfg),
    );
    print_entries(
        "Ablation 11 — span-recorder overhead (planned forward, off vs on)",
        &tracing_overhead(cfg),
    );
    print_precision_lanes(&precision_lanes(GanModel::DcGan, cfg));
    print_epilogue_fusion(&epilogue_fusion(GanModel::DcGan, cfg, &[1, 4, 8]));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            scale: 1.0,
            warmup: 0,
            iters: 2,
            workers: 2,
        }
    }

    #[test]
    fn formulation_entries_ordered_sanely() {
        let e = formulation(&quick());
        assert_eq!(e.len(), 4);
        // Phase-decomposed must beat conventional comfortably.
        assert!(e[3].seconds < e[0].seconds);
    }

    #[test]
    fn dilated_segregated_wins() {
        let e = dilated_routes(&quick());
        assert!(e[1].seconds < e[0].seconds, "{e:?}");
    }

    #[test]
    fn autotune_never_loses_to_serial_hand_pick() {
        // The winner of a search that *includes* the serial default can
        // only beat (or tie) it up to scheduler noise; allow 1.5× slack
        // for a 2-iteration CI box.
        let e = autotune(&quick());
        assert_eq!(e.len(), 3);
        assert!(
            e[2].seconds <= e[0].seconds * 1.5,
            "tuned {}s vs hand-picked serial {}s",
            e[2].seconds,
            e[0].seconds
        );
        for entry in &e {
            assert!(!entry.samples.is_empty());
        }
    }

    #[test]
    fn print_smoke() {
        print_entries(
            "smoke",
            &[
                Entry {
                    name: "a".into(),
                    seconds: 1.0,
                    samples: vec![1.0, 1.1],
                    macs: Some(2_000_000_000),
                },
                Entry {
                    name: "b".into(),
                    seconds: 0.5,
                    samples: vec![0.5, 0.6],
                    macs: None,
                },
            ],
        );
    }

    #[test]
    fn batch_fusion_covers_layers_and_batches() {
        let rows = batch_fusion(GanModel::GpGan, &quick(), &[1, 3]);
        assert_eq!(rows.len(), 2 * GanModel::GpGan.layers().len());
        for r in &rows {
            assert!(r.per_latent.seconds > 0.0 && r.fused.seconds > 0.0, "{}", r.layer);
            assert!(r.batch == 1 || r.batch == 3);
            assert_eq!(r.fused.macs, Some(r.macs));
        }
        print_batch_fusion(&rows);
    }

    #[test]
    fn backward_planning_covers_layers_and_batches() {
        let rows = backward_planning(GanModel::GpGan, &quick(), &[1, 3]);
        assert_eq!(rows.len(), 2 * GanModel::GpGan.layers().len());
        for r in &rows {
            assert!(
                r.unplanned.seconds > 0.0 && r.planned.seconds > 0.0,
                "{}",
                r.layer
            );
            assert!(r.batch == 1 || r.batch == 3);
            assert_eq!(r.planned.macs, Some(r.macs));
            assert_eq!(r.unplanned.macs, Some(r.macs));
        }
        print_backward_planning(&rows);
        // The snapshot document round-trips through the JSON layer with
        // every row and both training columns present.
        let train = training_step(&quick());
        assert_eq!(train.len(), 2);
        for e in &train {
            assert!(e.seconds > 0.0, "{}", e.name);
        }
        let doc = backward_snapshot_json(&rows, &train);
        let text = doc.to_string_compact();
        let parsed = crate::util::json::parse(&text).unwrap();
        let Some(Json::Arr(items)) = parsed.get("ablation10_backward") else {
            panic!("missing ablation10_backward array");
        };
        assert_eq!(items.len(), rows.len());
        assert!(items[0].get("planned_speedup").and_then(Json::as_f64).is_some());
        let Some(Json::Arr(ts)) = parsed.get("training_step") else {
            panic!("missing training_step array");
        };
        assert_eq!(ts.len(), 2);
    }

    #[test]
    fn observability_snapshot_has_rollup_registry_and_overhead() {
        // Serializes with the obs::trace unit tests — both toggle the
        // process-wide recorder flag.
        let _gate = trace::test_gate().lock().unwrap();
        let doc = observability_json(GanModel::smallest(), &quick());
        assert!(!trace::enabled(), "tracing must be restored to off");
        let text = doc.to_string_compact();
        let parsed = crate::util::json::parse(&text).unwrap();
        let Some(Json::Arr(rollup)) = parsed.get("trace_rollup") else {
            panic!("missing trace_rollup array");
        };
        // The traced DC-GAN forward yields at least the four layer
        // spans plus the projection and the model-level span.
        let names: Vec<&str> = rollup
            .iter()
            .filter_map(|r| r.get("name").and_then(Json::as_str))
            .collect();
        assert!(names.contains(&"layer.forward"), "{names:?}");
        assert!(names.contains(&"gen.forward"), "{names:?}");
        assert!(parsed.get("registry").and_then(|r| r.get("counters")).is_some());
        let Some(Json::Arr(overhead)) = parsed.get("tracing_overhead") else {
            panic!("missing tracing_overhead array");
        };
        assert_eq!(overhead.len(), 2);
    }

    #[test]
    fn precision_lanes_cover_stack() {
        let rows = precision_lanes(GanModel::GpGan, &quick());
        let layers = GanModel::GpGan.layers().len();
        assert_eq!(rows.len(), Precision::ALL.len() * layers);
        for chunk in rows.chunks(Precision::ALL.len()) {
            // Rows come in ALL order per layer; the f32 row is the
            // same lane as the reference, so its drift is exactly 0.
            assert_eq!(chunk[0].precision, Precision::F32);
            assert_eq!(chunk[0].max_abs, 0.0, "{}", chunk[0].layer);
            for r in chunk {
                assert!(r.entry.seconds > 0.0, "{}", r.layer);
                assert!(r.max_abs.is_finite(), "{}", r.layer);
                assert_eq!(r.entry.macs, Some(r.macs));
            }
            // Operand footprint must shrink with storage width: f16
            // and bf16 share one u16 layout at half the f32 bytes or
            // better, int8 at a quarter or better (QNR=8 padding can
            // only help the quantized side; panel width ≥ QNR).
            let f32b = chunk[0].packed_bytes;
            assert_eq!(chunk[1].packed_bytes, chunk[2].packed_bytes);
            assert!(f32b >= 2 * chunk[1].packed_bytes, "{}", chunk[0].layer);
            assert!(f32b >= 4 * chunk[3].packed_bytes, "{}", chunk[0].layer);
        }
        print_precision_lanes(&rows);
        // The snapshot section round-trips through the JSON layer.
        let doc = precision_json(GanModel::GpGan, &quick());
        let text = doc.to_string_compact();
        let parsed = crate::util::json::parse(&text).unwrap();
        let Json::Arr(items) = parsed else {
            panic!("precision section must be an array");
        };
        assert_eq!(items.len(), rows.len());
        assert_eq!(
            items[0].get("precision").and_then(Json::as_str),
            Some("f32")
        );
        assert!(items[0]
            .get("max_abs_vs_f32")
            .and_then(Json::as_f64)
            .is_some());
        assert!(items[0]
            .get("packed_operand_bytes")
            .and_then(Json::as_f64)
            .is_some());
    }

    #[test]
    fn epilogue_fusion_covers_layers_and_batches() {
        let rows = epilogue_fusion(GanModel::GpGan, &quick(), &[1, 3]);
        assert_eq!(rows.len(), 2 * GanModel::GpGan.layers().len());
        for r in &rows {
            assert!(r.separate.seconds > 0.0 && r.fused.seconds > 0.0, "{}", r.layer);
            assert!(r.batch == 1 || r.batch == 3);
            assert_eq!(r.fused.macs, Some(r.macs));
            assert_eq!(r.separate.macs, Some(r.macs));
            // Phases partition the output, so the analytic epilogue
            // traffic is exactly 7 floats (slab write+read, scatter
            // write, bias RMW, activation RMW) vs the single fused
            // store per output element.
            assert_eq!(r.separate_bytes, 7 * r.fused_bytes, "{}", r.layer);
            assert!(r.fused_bytes > 0);
        }
        print_epilogue_fusion(&rows);
        // The snapshot section round-trips through the JSON layer.
        let doc = fusion_json(GanModel::GpGan, &quick(), &[1, 3]);
        let text = doc.to_string_compact();
        let parsed = crate::util::json::parse(&text).unwrap();
        let Json::Arr(items) = parsed else {
            panic!("fusion section must be an array");
        };
        assert_eq!(items.len(), rows.len());
        assert!(items[0].get("fused_speedup").and_then(Json::as_f64).is_some());
        assert!(items[0]
            .get("separate_epilogue_bytes")
            .and_then(Json::as_f64)
            .is_some());
    }

    #[test]
    fn gemm_crossover_covers_layer_stack() {
        let rows = gemm_crossover(GanModel::GpGan, &quick());
        assert_eq!(rows.len(), GanModel::GpGan.layers().len());
        for r in &rows {
            assert!(r.direct.seconds > 0.0 && r.gemm.seconds > 0.0, "{}", r.layer);
            assert_eq!(r.direct.macs, Some(r.macs));
            assert!(r.macs > 0);
            // The ISA column reports the active microkernel; the
            // scalar A/B exists exactly when a vector lane is active.
            assert_eq!(r.isa, Isa::active());
            assert_eq!(r.gemm_scalar.is_some(), Isa::active() != Isa::Scalar);
            if let Some(e) = &r.gemm_scalar {
                assert!(e.seconds > 0.0);
            }
        }
        print_gemm_crossover(&rows);
    }
}
