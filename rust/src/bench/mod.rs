//! Benchmark harness: regenerates every table of the paper's
//! evaluation (criterion is unavailable offline, so `cargo bench` runs
//! these `harness = false` drivers; the same code backs the `ukstc`
//! CLI subcommands).
//!
//! * [`report`] — markdown table printing
//! * [`table2`] — Flower dataset sweep (paper Table 2)
//! * [`table3`] — MSCOCO + PASCAL sweep (paper Table 3)
//! * [`table4`] — GAN-layer ablation (paper Table 4)
//! * [`ablation`] — design-choice ablations beyond the paper's tables
//! * [`serving`] — coordinator throughput/latency A/B
//!
//! Measurement protocol: per-image cost is measured on a scaled sample
//! subset (`BenchConfig::scale`) and extrapolated to the full Table 1
//! sample counts — the computation is identical per image, so the
//! extrapolation is exact up to scheduler noise, and speedup ratios are
//! scale-invariant.

pub mod ablation;
pub mod report;
pub mod serving;
pub mod table2;
pub mod table3;
pub mod table4;

use crate::util::threadpool;

/// Common benchmark knobs.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Fraction of each dataset's samples to actually time (≥ 1 image).
    pub scale: f64,
    /// Unrecorded warmup iterations per measurement.
    pub warmup: usize,
    /// Recorded iterations per measurement.
    pub iters: usize,
    /// Workers for the parallel lane (the paper's "GPU" column).
    pub workers: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            scale: 0.02,
            warmup: 1,
            iters: 2,
            workers: threadpool::default_parallelism(),
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI / `cargo bench` smoke runs.
    pub fn quick() -> Self {
        BenchConfig {
            scale: 0.005,
            warmup: 0,
            iters: 1,
            ..Default::default()
        }
    }

    /// Number of images to time for a group with `samples` total.
    pub fn sample_count(&self, samples: usize) -> usize {
        ((samples as f64 * self.scale).round() as usize).clamp(1, samples)
    }
}

/// Geometric mean of speedups (the paper's "average speedup" is an
/// arithmetic mean; we report both, geomean is the robust one).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_count_clamps() {
        let cfg = BenchConfig {
            scale: 0.01,
            ..Default::default()
        };
        assert_eq!(cfg.sample_count(734), 7);
        assert_eq!(cfg.sample_count(10), 1); // min 1
        let full = BenchConfig {
            scale: 2.0,
            ..Default::default()
        };
        assert_eq!(full.sample_count(10), 10); // max samples
    }

    #[test]
    fn means() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
