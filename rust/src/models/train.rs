//! Generator training step (DESIGN.md §Backward-Execution).
//!
//! The paper's Table 5/6 measure the *backward* stage of the unified
//! kernel-segregated operation; this module closes the loop by running
//! a real generator training step over the planned backward lanes of
//! [`ConvTransposePlan`](crate::conv::plan::ConvTransposePlan):
//!
//! 1. [`Generator::forward_trace`] — the planned forward pass, keeping
//!    the per-layer **post-activation** maps (the only state backward
//!    needs: `tanh'` and `relu'` are both recoverable from the output).
//! 2. [`Generator::backward_trace`] — reverse chain over
//!    [`LayerWeights::backward_with`]: per layer an activation gate, a
//!    bias spatial sum, the planned data-grad lane (direct / phase-GEMM
//!    / phase-row-parallel, honoring pinned backward strategies) and the
//!    phase-GEMM weight-grad — all through **one** scratch arena — then
//!    the dense projection's gradient.
//! 3. [`Generator::sgd_step`] — plain SGD; layers are re-frozen
//!    ([`LayerWeights::new`]) because plans pack the segregated kernel
//!    at construction, and every strategy pin survives the rebuild.
//!
//! [`TrainStep`] bundles the three into the driver
//! `examples/training_step.rs` and the `training_step` bench column
//! run: a fixed latent, a fixed target image, MSE loss.

use crate::conv::parallel::{Algorithm, Lane};
use crate::conv::plan::Scratch;
use crate::obs::trace as obs_trace;
use crate::tensor::{ops, Feature, Kernel};
use crate::util::rng::Rng;

use super::forward::{Generator, LayerWeights};

/// Everything one backward pass needs from the forward pass: the
/// latent, the post-ReLU projection map, and each layer's
/// post-activation output (the last one is the generated image).
#[derive(Debug, Clone)]
pub struct ForwardTrace {
    pub z: Vec<f32>,
    /// Post-ReLU projection output (layer 0's input).
    pub x0: Feature,
    /// Per-layer post-activation outputs, in layer order.
    pub acts: Vec<Feature>,
}

impl ForwardTrace {
    /// The generated image (the last layer's post-tanh output).
    pub fn output(&self) -> &Feature {
        self.acts.last().expect("trace of an empty generator")
    }
}

/// Gradients of every generator parameter, shaped like the parameters.
#[derive(Debug, Clone)]
pub struct GeneratorGrads {
    pub proj_w: Vec<f32>,
    pub proj_b: Vec<f32>,
    /// Per-layer `(dkernel, dbias)`, in layer order.
    pub layers: Vec<(Kernel, Vec<f32>)>,
}

impl Generator {
    /// Forward pass that keeps what backward needs (planned unified
    /// path, honoring pinned forward strategies).  Per image the
    /// arithmetic is exactly [`forward_with`](Generator::forward_with);
    /// the trace stores one post-activation clone per layer.
    pub fn forward_trace(&self, z: &[f32], scratch: &mut Scratch) -> ForwardTrace {
        let _span = obs_trace::span("gen.forward", "model", obs_trace::NONE, obs_trace::NONE);
        let x0 = self.project(z);
        let mut acts = Vec::with_capacity(self.layers.len());
        let last = self.layers.len() - 1;
        let mut x = x0.clone();
        for (i, lw) in self.layers.iter().enumerate() {
            {
                // Table-4 numbering: the projection is layer 1.
                let _layer_span = obs_trace::span(
                    "layer.forward",
                    lw.lane_tag(),
                    (i + 2) as u32,
                    obs_trace::NONE,
                );
                x = lw.apply(&x, Algorithm::Unified, Lane::Serial, scratch);
            }
            ops::add_bias_inplace(&mut x, &lw.bias);
            if i == last {
                ops::tanh_inplace(&mut x);
            } else {
                ops::relu_inplace(&mut x);
            }
            acts.push(x.clone());
        }
        ForwardTrace {
            z: z.to_vec(),
            x0,
            acts,
        }
    }

    /// Reverse chain from `dy_out` (gradient w.r.t. the generated
    /// image) down to every parameter, through one scratch arena.
    pub fn backward_trace(
        &self,
        trace: &ForwardTrace,
        dy_out: &Feature,
        scratch: &mut Scratch,
    ) -> GeneratorGrads {
        let _span = obs_trace::span("gen.backward", "model", obs_trace::NONE, obs_trace::NONE);
        assert_eq!(trace.acts.len(), self.layers.len(), "trace/layer mismatch");
        let last = self.layers.len() - 1;
        let mut layer_grads: Vec<Option<(Kernel, Vec<f32>)>> = vec![None; self.layers.len()];
        let mut dy = dy_out.clone();
        for i in (0..self.layers.len()).rev() {
            let x = if i == 0 { &trace.x0 } else { &trace.acts[i - 1] };
            let _layer_span = obs_trace::span(
                "layer.backward",
                self.layers[i].backward_lane_tag(),
                (i + 2) as u32,
                obs_trace::NONE,
            );
            let (dx, dk, db) =
                self.layers[i].backward_with(x, &trace.acts[i], &dy, i == last, scratch);
            drop(_layer_span);
            layer_grads[i] = Some((dk, db));
            dy = dx;
        }
        // Projection: `dy` is now the gradient w.r.t. the post-ReLU
        // projection map.  Gate by the stored post-ReLU values, then
        // dW[zi, o] = z[zi]·dpre[o] (exactly zero for zero latents —
        // the same rows `project` skips).
        let mut dpre = dy;
        for (d, &v) in dpre.data.iter_mut().zip(&trace.x0.data) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let out_len = dpre.data.len();
        let mut proj_w = vec![0.0f32; self.proj_w.len()];
        for (zi, &zv) in trace.z.iter().enumerate() {
            if zv == 0.0 {
                continue;
            }
            let row = &mut proj_w[zi * out_len..(zi + 1) * out_len];
            for (g, &d) in row.iter_mut().zip(&dpre.data) {
                *g = zv * d;
            }
        }
        GeneratorGrads {
            proj_w,
            proj_b: dpre.data,
            layers: layer_grads.into_iter().map(Option::unwrap).collect(),
        }
    }

    /// One plain-SGD update: `w ← w − lr·g` for every parameter.  Each
    /// layer is rebuilt through [`LayerWeights::new`] — plans freeze
    /// the segregated, packed kernel at construction, so a weight
    /// update means a re-freeze — with both strategy pins preserved.
    pub fn sgd_step(&mut self, grads: &GeneratorGrads, lr: f32) {
        assert_eq!(grads.layers.len(), self.layers.len(), "grads/layer mismatch");
        assert_eq!(grads.proj_w.len(), self.proj_w.len());
        assert_eq!(grads.proj_b.len(), self.proj_b.len());
        for (w, g) in self.proj_w.iter_mut().zip(&grads.proj_w) {
            *w -= lr * g;
        }
        for (b, g) in self.proj_b.iter_mut().zip(&grads.proj_b) {
            *b -= lr * g;
        }
        for (lw, (dk, db)) in self.layers.iter_mut().zip(&grads.layers) {
            let mut kernel = lw.kernel.clone();
            for (w, g) in kernel.data.iter_mut().zip(&dk.data) {
                *w -= lr * g;
            }
            let mut bias = lw.bias.clone();
            for (b, g) in bias.iter_mut().zip(db) {
                *b -= lr * g;
            }
            let strategy = lw.strategy;
            let backward_strategy = lw.backward_strategy;
            let mut rebuilt = LayerWeights::new(lw.spec, kernel, bias);
            rebuilt.strategy = strategy;
            rebuilt.backward_strategy = backward_strategy;
            *lw = rebuilt;
        }
    }

    /// Exact arena floats a full training step needs: the max over
    /// layers of the forward figure joined with the backward figure
    /// (forward and backward share one arena).
    pub fn max_scratch_floats_train(&self) -> usize {
        self.layers
            .iter()
            .map(|lw| lw.scratch_floats().max(lw.scratch_floats_backward()))
            .max()
            .unwrap_or(0)
    }

    /// Arena sized for [`max_scratch_floats_train`](Self::max_scratch_floats_train).
    pub fn scratch_train(&self) -> Scratch {
        Scratch::with_floats(self.max_scratch_floats_train())
    }
}

/// A self-contained supervised training driver: a fixed latent, a
/// fixed target image in tanh range, MSE loss, plain SGD — the
/// smallest loop that exercises every backward lane end to end (what
/// `examples/training_step.rs` and the `training_step` bench column
/// run).
#[derive(Debug)]
pub struct TrainStep {
    pub gen: Generator,
    /// Fixed regression target (tanh range).
    pub target: Feature,
    /// SGD step size.
    pub lr: f32,
    scratch: Scratch,
    z: Vec<f32>,
}

impl TrainStep {
    /// Fixed latent and target drawn from `rng`; arena pre-sized for
    /// the whole step.
    pub fn new(gen: Generator, rng: &mut Rng, lr: f32) -> TrainStep {
        let z: Vec<f32> = (0..gen.model.z_dim()).map(|_| rng.normal_f32()).collect();
        let (h, w, c) = gen.output_shape();
        let mut target = Feature::zeros(h, w, c);
        for v in &mut target.data {
            *v = (0.5 * rng.normal_f32()).tanh();
        }
        let scratch = gen.scratch_train();
        TrainStep {
            gen,
            target,
            lr,
            scratch,
            z,
        }
    }

    /// MSE between an image and the target.
    fn mse(&self, y: &Feature) -> f32 {
        let n = y.data.len() as f32;
        y.data
            .iter()
            .zip(&self.target.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n
    }

    /// Current loss (forward only, no update).
    pub fn loss(&mut self) -> f32 {
        let trace = self.gen.forward_trace(&self.z, &mut self.scratch);
        self.mse(trace.output())
    }

    /// One full step: forward → MSE loss → backward → SGD update.
    /// Returns the loss *before* the update, so a strictly decreasing
    /// sequence of returns certifies the gradients point downhill.
    pub fn step(&mut self) -> f32 {
        let _span = obs_trace::span("train.step", "model", obs_trace::NONE, obs_trace::NONE);
        let trace = {
            let _s = obs_trace::span("train.forward", "model", obs_trace::NONE, obs_trace::NONE);
            self.gen.forward_trace(&self.z, &mut self.scratch)
        };
        let y = trace.output();
        let (loss, dy) = {
            let _s = obs_trace::span("train.loss", "model", obs_trace::NONE, obs_trace::NONE);
            let loss = self.mse(y);
            let n = y.data.len() as f32;
            let mut dy = Feature::zeros(y.h, y.w, y.c);
            for ((d, &a), &b) in dy.data.iter_mut().zip(&y.data).zip(&self.target.data) {
                *d = 2.0 * (a - b) / n;
            }
            (loss, dy)
        };
        let grads = {
            let _s = obs_trace::span("train.backward", "model", obs_trace::NONE, obs_trace::NONE);
            self.gen.backward_trace(&trace, &dy, &mut self.scratch)
        };
        {
            let _s = obs_trace::span("train.sgd", "model", obs_trace::NONE, obs_trace::NONE);
            self.gen.sgd_step(&grads, self.lr);
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{GanModel, LayerSpec};
    use crate::tensor::Kernel;

    /// Two tiny layers over the GpGan skeleton (the forward.rs test
    /// fixture, rebuilt here: test helpers don't cross module tests).
    fn tiny_generator() -> Generator {
        let mut rng = Rng::seeded(60);
        let mut g = Generator::random(GanModel::GpGan, &mut rng);
        let specs = [LayerSpec::gan(4, 8, 6), LayerSpec::gan(8, 6, 3)];
        g.layers = specs
            .iter()
            .map(|&spec| {
                let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
                LayerWeights::new(spec, kernel, vec![0.01; spec.cout])
            })
            .collect();
        let z = g.model.z_dim();
        let out0 = 4 * 4 * 8;
        g.proj_w = vec![0.02; z * out0];
        g.proj_b = vec![0.0; out0];
        g
    }

    fn loss_of(g: &Generator, z: &[f32], target: &Feature) -> f32 {
        let y = g.forward(z, Algorithm::Unified, Lane::Serial);
        let n = y.data.len() as f32;
        y.data
            .iter()
            .zip(&target.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n
    }

    #[test]
    fn generator_grads_match_finite_differences() {
        // Central FD over a spread of parameters of every kind —
        // projection weights/biases, both layers' kernels and biases —
        // against the analytic chain.  eps/tol follow the repo's FD
        // contract (f32 arithmetic).
        let g = tiny_generator();
        let mut rng = Rng::seeded(71);
        let z: Vec<f32> = (0..g.model.z_dim()).map(|_| rng.normal_f32()).collect();
        let (h, w, c) = g.output_shape();
        let mut target = Feature::zeros(h, w, c);
        for v in &mut target.data {
            *v = (0.5 * rng.normal_f32()).tanh();
        }
        let mut scratch = g.scratch_train();
        let trace = g.forward_trace(&z, &mut scratch);
        let y = trace.output();
        let n = y.data.len() as f32;
        let mut dy = Feature::zeros(y.h, y.w, y.c);
        for ((d, &a), &b) in dy.data.iter_mut().zip(&y.data).zip(&target.data) {
            *d = 2.0 * (a - b) / n;
        }
        let grads = g.backward_trace(&trace, &dy, &mut scratch);
        let eps = 1e-2f32;
        let check = |got: f32, fd: f32, what: &str| {
            assert!(
                (got - fd).abs() <= 2e-2 * (1.0 + fd.abs()),
                "{what}: analytic {got} vs FD {fd}"
            );
        };
        // Projection weights: a deterministic spread of indices.
        for i in (0..g.proj_w.len()).step_by(g.proj_w.len() / 5 + 1) {
            let mut gp = g.clone();
            gp.proj_w[i] += eps;
            let mut gm = g.clone();
            gm.proj_w[i] -= eps;
            let fd = (loss_of(&gp, &z, &target) - loss_of(&gm, &z, &target)) / (2.0 * eps);
            check(grads.proj_w[i], fd, &format!("proj_w[{i}]"));
        }
        for i in (0..g.proj_b.len()).step_by(g.proj_b.len() / 4 + 1) {
            let mut gp = g.clone();
            gp.proj_b[i] += eps;
            let mut gm = g.clone();
            gm.proj_b[i] -= eps;
            let fd = (loss_of(&gp, &z, &target) - loss_of(&gm, &z, &target)) / (2.0 * eps);
            check(grads.proj_b[i], fd, &format!("proj_b[{i}]"));
        }
        // Kernels and biases of both layers: perturbing a kernel means
        // re-freezing the layer's plan.
        for li in 0..g.layers.len() {
            let klen = g.layers[li].kernel.data.len();
            for i in (0..klen).step_by(klen / 5 + 1) {
                let fd_at = |sign: f32| {
                    let mut gg = g.clone();
                    let mut kernel = gg.layers[li].kernel.clone();
                    kernel.data[i] += sign * eps;
                    let bias = gg.layers[li].bias.clone();
                    gg.layers[li] = LayerWeights::new(gg.layers[li].spec, kernel, bias);
                    loss_of(&gg, &z, &target)
                };
                let fd = (fd_at(1.0) - fd_at(-1.0)) / (2.0 * eps);
                check(grads.layers[li].0.data[i], fd, &format!("layer{li}.kernel[{i}]"));
            }
            for i in 0..g.layers[li].bias.len() {
                let fd_at = |sign: f32| {
                    let mut gg = g.clone();
                    gg.layers[li].bias[i] += sign * eps;
                    loss_of(&gg, &z, &target)
                };
                let fd = (fd_at(1.0) - fd_at(-1.0)) / (2.0 * eps);
                check(grads.layers[li].1[i], fd, &format!("layer{li}.bias[{i}]"));
            }
        }
    }

    #[test]
    fn train_step_loss_strictly_decreases() {
        // The CI gate in miniature: a few SGD steps on the MSE
        // objective must move strictly downhill.
        let g = tiny_generator();
        let mut rng = Rng::seeded(72);
        let mut ts = TrainStep::new(g, &mut rng, 0.05);
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(ts.step());
        }
        for w in losses.windows(2) {
            assert!(w[1] < w[0], "loss did not decrease: {losses:?}");
        }
        // And the post-update loss agrees with the next step's report.
        let final_loss = ts.loss();
        assert!(final_loss < *losses.last().unwrap());
    }

    #[test]
    fn backward_trace_consistent_across_lanes_and_sgd_keeps_pins() {
        // Pinned backward strategies change speed, not gradients: the
        // GEMM and parallel data-grad lanes must agree with the direct
        // chain within the 1e-4 reassociation contract, and SGD
        // rebuilds must preserve every pin.
        use crate::tune::space::{backward_search_space, ExecStrategy};
        let g = tiny_generator();
        let mut rng = Rng::seeded(73);
        let z: Vec<f32> = (0..g.model.z_dim()).map(|_| rng.normal_f32()).collect();
        let dy = Feature::random(16, 16, 3, &mut rng);
        let mut scratch = g.scratch_train();
        let trace = g.forward_trace(&z, &mut scratch);
        let want = g.backward_trace(&trace, &dy, &mut scratch);
        for s in backward_search_space(3) {
            let mut gp = g.clone();
            gp.set_backward_strategies(&[s, s]);
            let mut scratch_p = gp.scratch_train();
            let trace_p = gp.forward_trace(&z, &mut scratch_p);
            assert_eq!(trace_p.output(), trace.output(), "forward must not change");
            let got = gp.backward_trace(&trace_p, &dy, &mut scratch_p);
            let err = got
                .proj_w
                .iter()
                .zip(&want.proj_w)
                .chain(got.proj_b.iter().zip(&want.proj_b))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 1e-4, "{} grads diverged by {err}", s.name());
            // SGD keeps the pins through the plan re-freeze.
            gp.sgd_step(&got, 0.01);
            assert!(gp.backward_strategies().iter().all(|p| *p == Some(s)));
        }
        // A forward pin survives too.
        let mut gf = g.clone();
        gf.set_strategies(&[ExecStrategy::serial_gemm(), ExecStrategy::serial()]);
        let mut scratch_f = gf.scratch_train();
        let trace_f = gf.forward_trace(&z, &mut scratch_f);
        let grads_f = gf.backward_trace(&trace_f, &dy, &mut scratch_f);
        gf.sgd_step(&grads_f, 0.01);
        assert_eq!(gf.strategies()[0], Some(ExecStrategy::serial_gemm()));
    }
}
