//! The GAN model zoo — Table 4's layer tables, transcribed verbatim.
//!
//! Every generator is a stack of `ConvTranspose2d(k=4, s=2, p=1)`
//! blocks (paper padding factor `P = 2`), each doubling the spatial
//! size.  The ArtGAN "4×4×246×128" kernel entry is a typo in the paper
//! for 128 input channels (the input-size column says 16×16×**128**);
//! we keep the input-size column as ground truth.

use crate::conv::ConvTransposeParams;

/// One transpose-conv layer of a generator (a Table 4 row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    /// Input spatial size `N` (square).
    pub n_in: usize,
    pub cin: usize,
    pub cout: usize,
    /// Kernel size (always 4 in Table 4).
    pub ksize: usize,
    /// Paper padding factor `P` (always 2 in Table 4).
    pub padding: usize,
}

impl LayerSpec {
    pub const fn gan(n_in: usize, cin: usize, cout: usize) -> LayerSpec {
        LayerSpec {
            n_in,
            cin,
            cout,
            ksize: 4,
            padding: 2,
        }
    }

    /// Output spatial size (`2N` for the standard GAN block).
    pub fn n_out(&self) -> usize {
        crate::conv::out_size(self.n_in, self.ksize, self.padding)
    }

    /// Conversion to the conv-geometry struct.
    pub fn params(&self) -> ConvTransposeParams {
        ConvTransposeParams::new(self.n_in, self.ksize, self.padding, self.cin, self.cout)
    }

    /// Human-readable shape for the tune/bench tables,
    /// e.g. `4×4×512→256 k4 P2`.
    pub fn describe(&self) -> String {
        format!(
            "{0}×{0}×{1}→{2} k{3} P{4}",
            self.n_in, self.cin, self.cout, self.ksize, self.padding
        )
    }
}

/// Which GAN the layer stack comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GanModel {
    /// DC-GAN and DiscoGAN share a generator (Radford'15 / Kim'17).
    DcGan,
    ArtGan,
    GpGan,
    EbGan,
}

impl GanModel {
    pub fn name(&self) -> &'static str {
        match self {
            GanModel::DcGan => "dcgan",
            GanModel::ArtGan => "artgan",
            GanModel::GpGan => "gpgan",
            GanModel::EbGan => "ebgan",
        }
    }

    pub fn from_name(name: &str) -> Option<GanModel> {
        match name {
            "dcgan" | "discogan" => Some(GanModel::DcGan),
            "artgan" => Some(GanModel::ArtGan),
            "gpgan" | "gp-gan" => Some(GanModel::GpGan),
            "ebgan" | "eb-gan" => Some(GanModel::EbGan),
            _ => None,
        }
    }

    pub fn all() -> [GanModel; 4] {
        [
            GanModel::DcGan,
            GanModel::ArtGan,
            GanModel::GpGan,
            GanModel::EbGan,
        ]
    }

    /// The transpose-conv layer stack (Table 4 rows, top to bottom).
    pub fn layers(&self) -> &'static [LayerSpec] {
        static DCGAN: [LayerSpec; 4] = [
            LayerSpec::gan(4, 1024, 512),
            LayerSpec::gan(8, 512, 256),
            LayerSpec::gan(16, 256, 128),
            LayerSpec::gan(32, 128, 3),
        ];
        static ARTGAN: [LayerSpec; 4] = [
            LayerSpec::gan(4, 512, 256),
            LayerSpec::gan(8, 256, 128),
            LayerSpec::gan(16, 128, 128),
            LayerSpec::gan(32, 128, 3),
        ];
        static GPGAN: [LayerSpec; 4] = [
            LayerSpec::gan(4, 512, 256),
            LayerSpec::gan(8, 256, 128),
            LayerSpec::gan(16, 128, 64),
            LayerSpec::gan(32, 64, 3),
        ];
        static EBGAN: [LayerSpec; 6] = [
            LayerSpec::gan(4, 2048, 1024),
            LayerSpec::gan(8, 1024, 512),
            LayerSpec::gan(16, 512, 256),
            LayerSpec::gan(32, 256, 128),
            LayerSpec::gan(64, 128, 64),
            LayerSpec::gan(128, 64, 64),
        ];
        match self {
            GanModel::DcGan => &DCGAN,
            GanModel::ArtGan => &ARTGAN,
            GanModel::GpGan => &GPGAN,
            GanModel::EbGan => &EBGAN,
        }
    }

    /// Latent dimension of the generator input (standard DCGAN setting).
    pub fn z_dim(&self) -> usize {
        100
    }

    /// The cheapest zoo entry by analytic conventional FLOPs — what
    /// the CI `ukstc tune` smoke run and quick experiments target.
    pub fn smallest() -> GanModel {
        GanModel::all()
            .into_iter()
            .min_by_key(|m| {
                m.layers()
                    .iter()
                    .map(|l| crate::conv::flops::conventional(&l.params()))
                    .sum::<u64>()
            })
            .unwrap()
    }

    /// Total Table 4 memory savings (bytes) for this model's layers.
    pub fn total_memory_savings(&self) -> usize {
        self.layers()
            .iter()
            .map(|l| crate::conv::memory::savings_table4(&l.params()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_stacks_chain() {
        for model in GanModel::all() {
            let layers = model.layers();
            for pair in layers.windows(2) {
                assert_eq!(pair[0].n_out(), pair[1].n_in, "{}", model.name());
                assert_eq!(pair[0].cout, pair[1].cin, "{}", model.name());
            }
        }
    }

    #[test]
    fn every_layer_doubles() {
        for model in GanModel::all() {
            for l in model.layers() {
                assert_eq!(l.n_out(), 2 * l.n_in);
            }
        }
    }

    #[test]
    fn table4_totals_match_paper() {
        assert_eq!(GanModel::DcGan.total_memory_savings(), 4_787_712);
        assert_eq!(GanModel::EbGan.total_memory_savings(), 35_534_592);
        assert_eq!(GanModel::GpGan.total_memory_savings(), 2_393_856);
    }

    #[test]
    fn name_roundtrip() {
        for model in GanModel::all() {
            assert_eq!(GanModel::from_name(model.name()), Some(model));
        }
        assert_eq!(GanModel::from_name("discogan"), Some(GanModel::DcGan));
        assert_eq!(GanModel::from_name("vae"), None);
    }

    #[test]
    fn smallest_is_gpgan() {
        // GP-GAN's stack is dominated layer-for-layer by every other
        // entry (ArtGAN shares its first two rows but widens layers
        // 3–4), so it is the analytic minimum.
        assert_eq!(GanModel::smallest(), GanModel::GpGan);
    }

    #[test]
    fn describe_mentions_geometry() {
        let d = LayerSpec::gan(4, 512, 256).describe();
        assert!(d.contains("4×4×512→256"), "{d}");
        assert!(d.contains("k4") && d.contains("P2"), "{d}");
    }

    #[test]
    fn ebgan_final_resolution() {
        let last = GanModel::EbGan.layers().last().unwrap();
        assert_eq!(last.n_out(), 256);
        assert_eq!(last.cout, 64);
    }
}
