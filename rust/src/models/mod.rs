//! GAN generator models (the paper's ablation workload, Table 4).
//!
//! * [`zoo`] — layer tables for DC-GAN/DiscoGAN, ArtGAN, GP-GAN, EB-GAN
//!   transcribed verbatim from Table 4
//! * [`forward`] — generator forward pass over any conv
//!   [`Algorithm`](crate::conv::parallel::Algorithm)/[`Lane`](crate::conv::parallel::Lane)
//! * [`train`] — the training step (DESIGN.md §Backward-Execution):
//!   forward trace → planned backward lanes → SGD, driven by
//!   [`TrainStep`]
//!
//! These are the *Rust-native* models used by the paper-table benches;
//! the serving path runs the AOT-compiled JAX twins (see
//! [`crate::runtime`]), and the integration tests check the two stay
//! numerically consistent via the shared golden vectors.

pub mod forward;
pub mod train;
pub mod zoo;

pub use forward::{Generator, LayerWeights};
pub use train::{ForwardTrace, GeneratorGrads, TrainStep};
pub use zoo::{GanModel, LayerSpec};
