//! Generator forward pass over the Rust conv backends.
//!
//! Mirrors `python/compile/model.py::generator_fwd`: dense projection of
//! the latent, reshape to 4×4, N transpose-conv blocks (ReLU between,
//! tanh last).  The conv algorithm and lane are injected so the same
//! model definition drives the paper benches (conventional vs grouped
//! vs unified, serial vs parallel).
//!
//! Every layer carries an ahead-of-time [`ConvTransposePlan`] built at
//! construction (DESIGN.md §Plan-Execute): the unified algorithm
//! executes through the plan and a caller-supplied [`Scratch`] arena, so
//! steady-state serving performs no per-layer planning and no scratch
//! allocations.  One arena, sized for the largest layer, is threaded
//! through the whole stack.

use crate::conv::gemm;
use crate::conv::parallel::{run_seg, Algorithm, Lane};
use crate::conv::plan::{ConvTransposePlan, Scratch};
use crate::conv::segregation::Segregated;
use crate::obs::trace;
use crate::tensor::{ops, Feature, FeatureBatch, Kernel};
use crate::tune::space::ExecStrategy;
use crate::util::rng::Rng;

use super::zoo::{GanModel, LayerSpec};

/// Weights of one transpose-conv block.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub spec: LayerSpec,
    pub kernel: Kernel,
    /// Ahead-of-time plan: the pre-segregated kernel plus frozen phase
    /// geometry, slab windows and exact scratch sizing — built once at
    /// construction (deployment-realistic: weights are prepared once,
    /// reused per request).
    pub plan: ConvTransposePlan,
    pub bias: Vec<f32>,
    /// Pinned per-layer execution strategy (DESIGN.md §Autotuning).
    /// When set, the unified algorithm executes the plan under it,
    /// overriding the caller's `Lane` — bit-identical either way; only
    /// speed changes.  `None` = the caller's lane decides (the
    /// pre-autotuner behavior).
    pub strategy: Option<ExecStrategy>,
    /// Pinned backward-pass strategy (DESIGN.md §Backward-Execution):
    /// the data-grad lane [`backward_with`](Self::backward_with) runs —
    /// direct, phase-GEMM, or phase-row-parallel — typically the
    /// `bwd`-keyed winner of `Tuner::tune_layer_backward_cached`.
    /// `None` = the serial direct lane.
    pub backward_strategy: Option<ExecStrategy>,
}

impl LayerWeights {
    /// Build the layer: segregates the kernel and freezes the plan.
    pub fn new(spec: LayerSpec, kernel: Kernel, bias: Vec<f32>) -> LayerWeights {
        let plan = ConvTransposePlan::new(spec.params(), &kernel);
        LayerWeights {
            spec,
            kernel,
            plan,
            bias,
            strategy: None,
            backward_strategy: None,
        }
    }

    /// Pin an autotuned execution strategy on this layer.
    pub fn with_strategy(mut self, strategy: ExecStrategy) -> LayerWeights {
        self.strategy = Some(strategy);
        self
    }

    /// Pin an autotuned backward-pass strategy on this layer.
    pub fn with_backward_strategy(mut self, strategy: ExecStrategy) -> LayerWeights {
        self.backward_strategy = Some(strategy);
        self
    }

    /// The pre-segregated kernel (owned by the plan).
    pub fn seg(&self) -> &Segregated {
        self.plan.seg()
    }

    /// Trace-lane tag of this layer's pinned forward strategy
    /// (`direct` when none is pinned — the lane-driven dispatches all
    /// run the direct formulation).
    pub fn lane_tag(&self) -> &'static str {
        self.strategy.as_ref().map_or("direct", ExecStrategy::lane_tag)
    }

    /// Trace-lane tag of the pinned backward strategy (`direct` when
    /// unpinned, matching [`backward_with`](Self::backward_with)).
    pub fn backward_lane_tag(&self) -> &'static str {
        self.backward_strategy
            .as_ref()
            .map_or("direct", ExecStrategy::lane_tag)
    }

    /// One transpose conv under `alg`/`lane`.  The unified algorithm
    /// takes the planned path through `scratch` (zero steady-state
    /// allocations beyond the output) — under the pinned
    /// [`ExecStrategy`] when one is set, else under the caller's lane;
    /// other algorithms fall back to the per-call kernels.
    pub fn apply(&self, x: &Feature, alg: Algorithm, lane: Lane, scratch: &mut Scratch) -> Feature {
        if alg == Algorithm::Unified {
            if let Some(strategy) = &self.strategy {
                let mut out = self.plan.new_output();
                self.plan.run_with(strategy, x, scratch, &mut out);
                return out;
            }
        }
        match (alg, lane) {
            (Algorithm::Unified, Lane::Serial) => self.plan.run_alloc(x, scratch),
            (Algorithm::Unified, Lane::Parallel(w)) => {
                let mut out = self.plan.new_output();
                self.plan.run_par(x, scratch, &mut out, w);
                out
            }
            _ => self.apply_unplanned(x, alg, lane),
        }
    }

    /// One transpose conv **with its layer epilogue** — per-channel
    /// bias plus the activation (`tanh` when `last`, ReLU otherwise) —
    /// in a single call.  When the pinned strategy carries the
    /// fused-epilogue axis (DESIGN.md §Fused-Epilogue), the planned
    /// GEMM lane applies bias+activation in-register as each tile
    /// stores into the strided output and the separate post-pass is
    /// skipped entirely; every other dispatch runs the historic
    /// conv-then-apply sequence.  Either way the result equals
    /// [`apply`](Self::apply) followed by bias + activation within the
    /// lane's accuracy contract (bit-identical off the fused lanes).
    pub fn apply_act(
        &self,
        x: &Feature,
        alg: Algorithm,
        lane: Lane,
        last: bool,
        scratch: &mut Scratch,
    ) -> Feature {
        let act = if last {
            gemm::Activation::Tanh
        } else {
            gemm::Activation::Relu
        };
        if alg == Algorithm::Unified {
            if let Some(strategy) = &self.strategy {
                let epi = gemm::Epilogue {
                    bias: Some(&self.bias),
                    act,
                };
                let mut out = self.plan.new_output();
                self.plan
                    .run_with_epilogue(strategy, x, scratch, &mut out, &epi);
                return out;
            }
        }
        let mut out = self.apply(x, alg, lane, scratch);
        ops::add_bias_inplace(&mut out, &self.bias);
        if last {
            ops::tanh_inplace(&mut out);
        } else {
            ops::relu_inplace(&mut out);
        }
        out
    }

    /// Pre-plan dispatch (per-call geometry + buffer allocation) — the
    /// comparison lane for the planned-vs-unplanned ablation and A/B
    /// serving bench.
    pub fn apply_unplanned(&self, x: &Feature, alg: Algorithm, lane: Lane) -> Feature {
        run_seg(alg, lane, x, &self.kernel, self.seg(), self.spec.padding)
    }

    /// Scratch floats this layer's execution actually needs: the full
    /// GEMM-inclusive requirement when a PhaseGemm strategy is pinned,
    /// the direct requirement otherwise (lane-driven dispatch only
    /// ever runs the direct paths) — so direct-only serving never
    /// pays for the im2col patch region.
    pub fn scratch_floats(&self) -> usize {
        match &self.strategy {
            Some(s) => self.plan.scratch_floats_for(s),
            None => self.plan.scratch_floats_direct(),
        }
    }

    /// One fused batched transpose conv (DESIGN.md §Batched-Execution):
    /// under the pinned strategy when one is set — through the plan's
    /// fused batched lanes when the strategy is fused, as a per-latent
    /// loop of the single-image lane otherwise (the tuner's A/B) — or
    /// under the caller's lane when no strategy is pinned.  The direct
    /// dispatches are bit-identical to `N` sequential
    /// [`apply`](Self::apply) calls.
    pub fn apply_batch(
        &self,
        x: &FeatureBatch,
        lane: Lane,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
    ) {
        match &self.strategy {
            Some(s) if s.fused => self.plan.run_batch_with(s, x, scratch, out),
            Some(s) => {
                // One input/output pair reused across the whole loop —
                // the per-latent pin costs two image copies per latent,
                // never a per-latent heap allocation (the planned lanes
                // overwrite every element, so reuse is safe).
                let mut xi = Feature::zeros(x.h, x.w, x.c);
                let mut oi = self.plan.new_output();
                for i in 0..x.n {
                    xi.data.copy_from_slice(x.image(i));
                    self.plan.run_with(s, &xi, scratch, &mut oi);
                    out.image_mut(i).copy_from_slice(&oi.data);
                }
            }
            None => match lane {
                Lane::Serial => self.plan.run_batch(x, scratch, out),
                Lane::Parallel(w) => self.plan.run_batch_par(x, scratch, out, w),
            },
        }
    }

    /// Batched analogue of [`apply_act`](Self::apply_act): the whole
    /// micro-batch through the conv **and** its bias+activation
    /// epilogue.  A pinned fused-epilogue strategy stores the epilogue
    /// in-register from the batched GEMM tiles; other pins route the
    /// per-latent or batched lane and finish with the separate
    /// epilogue pass; unpinned dispatch keeps the historic
    /// conv-then-apply sequence bit-identically.
    pub fn apply_batch_act(
        &self,
        x: &FeatureBatch,
        lane: Lane,
        last: bool,
        scratch: &mut Scratch,
        out: &mut FeatureBatch,
    ) {
        let act = if last {
            gemm::Activation::Tanh
        } else {
            gemm::Activation::Relu
        };
        let epi = gemm::Epilogue {
            bias: Some(&self.bias[..]),
            act,
        };
        match &self.strategy {
            Some(s) if s.fused => self.plan.run_batch_with_epilogue(s, x, scratch, out, &epi),
            Some(s) => {
                // Per-latent pin: one input/output pair reused across
                // the loop (see `apply_batch`), epilogue fused or
                // separate per the strategy's axis.
                let mut xi = Feature::zeros(x.h, x.w, x.c);
                let mut oi = self.plan.new_output();
                for i in 0..x.n {
                    xi.data.copy_from_slice(x.image(i));
                    self.plan.run_with_epilogue(s, &xi, scratch, &mut oi, &epi);
                    out.image_mut(i).copy_from_slice(&oi.data);
                }
            }
            None => {
                self.apply_batch(x, lane, scratch, out);
                ops::add_bias_batch_inplace(out, &self.bias);
                if last {
                    ops::tanh_batch_inplace(out);
                } else {
                    ops::relu_batch_inplace(out);
                }
            }
        }
    }

    /// Scratch floats the batched execution of this layer needs at
    /// batch size `n` under `lane` (the batched analogue of
    /// [`scratch_floats`](Self::scratch_floats)): lane-driven serial
    /// dispatch loops one direct region, so it never pays the
    /// image-parallel lane's `n×` regions.
    pub fn scratch_floats_batch(&self, n: usize, lane: Lane) -> usize {
        match &self.strategy {
            Some(s) if s.fused => self.plan.scratch_floats_for_batch(s, n),
            Some(s) => self.plan.scratch_floats_for(s),
            None => match lane {
                Lane::Serial => self.plan.scratch_floats_direct(),
                // The image-parallel direct lane owns one direct
                // region per image.
                Lane::Parallel(_) => self.plan.scratch_floats_batch_par(n),
            },
        }
    }

    /// One full layer backward step (DESIGN.md §Backward-Execution).
    ///
    /// Inputs are the layer's forward input `x`, its **post-activation**
    /// output `y_post`, and the incoming gradient `dy` w.r.t. that
    /// output.  The activation derivative is recovered from the
    /// post-activation value alone — `tanh'` as `1 − y²` when `last`,
    /// `relu'` as the sign gate `y > 0` otherwise — so the forward
    /// trace never stores pre-activation maps.  Returns
    /// `(dx, dkernel, dbias)`; both conv gradients run the **fused**
    /// backward ([`ConvTransposePlan::run_backward_with`]), which
    /// extracts each `dy` phase once and shares it between the
    /// weight-grad GEMM and the data-grad lane — the pinned
    /// [`backward_strategy`](Self::backward_strategy) when one is set,
    /// the serial direct lane otherwise — through `scratch`.
    pub fn backward_with(
        &self,
        x: &Feature,
        y_post: &Feature,
        dy: &Feature,
        last: bool,
        scratch: &mut Scratch,
    ) -> (Feature, Kernel, Vec<f32>) {
        assert_eq!(
            (dy.h, dy.w, dy.c),
            (y_post.h, y_post.w, y_post.c),
            "layer backward: dy / y_post shape mismatch"
        );
        let mut dpre = dy.clone();
        if last {
            for (d, &y) in dpre.data.iter_mut().zip(&y_post.data) {
                *d *= 1.0 - y * y;
            }
        } else {
            for (d, &y) in dpre.data.iter_mut().zip(&y_post.data) {
                if y <= 0.0 {
                    *d = 0.0;
                }
            }
        }
        // Bias grad: per-channel spatial sum of the pre-activation grad
        // (bias is broadcast-added over the spatial grid in `apply`).
        let cout = self.spec.cout;
        let mut db = vec![0.0f32; cout];
        for px in dpre.data.chunks_exact(cout) {
            for (b, &v) in db.iter_mut().zip(px) {
                *b += v;
            }
        }
        let mut dx = self.plan.new_input_grad();
        let mut dk = self.plan.new_kernel_grad();
        let serial = ExecStrategy::serial();
        let strategy = self.backward_strategy.as_ref().unwrap_or(&serial);
        self.plan
            .run_backward_with(strategy, x, &dpre, scratch, &mut dx, &mut dk);
        (dx, dk, db)
    }

    /// Scratch floats [`backward_with`](Self::backward_with) needs:
    /// the fused backward figure — one shared dense-phase region plus
    /// the larger of the forward/backward im2col patches — which covers
    /// every data-grad lane a pin can select.
    pub fn scratch_floats_backward(&self) -> usize {
        self.plan.scratch_floats_backward_fused()
    }
}

/// A generator with materialized weights.
#[derive(Debug, Clone)]
pub struct Generator {
    pub model: GanModel,
    /// Dense projection `z[z_dim] → 4·4·C0` (row-major `[z_dim, out]`).
    pub proj_w: Vec<f32>,
    pub proj_b: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl Generator {
    /// He-style random initialization (matches the scale convention of
    /// `python/compile/model.py::init_params`).
    pub fn random(model: GanModel, rng: &mut Rng) -> Generator {
        let layers_spec = model.layers();
        let c0 = layers_spec[0].cin;
        let n0 = layers_spec[0].n_in;
        let z = model.z_dim();
        let proj_out = n0 * n0 * c0;
        let scale_proj = 1.0 / (z as f32).sqrt();
        let mut proj_w = vec![0.0f32; z * proj_out];
        rng.fill_normal(&mut proj_w);
        for v in &mut proj_w {
            *v *= scale_proj;
        }
        let mut proj_b = vec![0.0f32; proj_out];
        rng.fill_normal(&mut proj_b);
        let layers = layers_spec
            .iter()
            .map(|&spec| {
                let mut kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, rng);
                let scale = 1.0 / (spec.ksize as f32);
                for v in &mut kernel.data {
                    *v *= scale;
                }
                let mut bias = vec![0.0f32; spec.cout];
                rng.fill_normal(&mut bias);
                for v in &mut bias {
                    *v *= 0.01;
                }
                LayerWeights::new(spec, kernel, bias)
            })
            .collect();
        Generator {
            model,
            proj_w,
            proj_b,
            layers,
        }
    }

    /// Latent → first feature map (dense + ReLU).
    pub fn project(&self, z: &[f32]) -> Feature {
        let _span = trace::span("gen.project", "dense", trace::NONE, trace::NONE);
        let spec0 = self.layers[0].spec;
        let (n0, c0) = (spec0.n_in, spec0.cin);
        let out_len = n0 * n0 * c0;
        let z_dim = self.model.z_dim();
        assert_eq!(z.len(), z_dim, "latent length mismatch");
        let mut out = self.proj_b.clone();
        debug_assert_eq!(out.len(), out_len);
        for (zi, &zv) in z.iter().enumerate() {
            if zv == 0.0 {
                continue;
            }
            let row = &self.proj_w[zi * out_len..(zi + 1) * out_len];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += zv * w;
            }
        }
        let mut f = Feature::from_vec(n0, n0, c0, out);
        ops::relu_inplace(&mut f);
        f
    }

    /// Pin per-layer execution strategies (e.g. the autotuner's
    /// winners, in layer order).  Panics on a length mismatch.
    pub fn set_strategies(&mut self, strategies: &[ExecStrategy]) {
        assert_eq!(
            strategies.len(),
            self.layers.len(),
            "one strategy per layer"
        );
        for (lw, s) in self.layers.iter_mut().zip(strategies) {
            lw.strategy = Some(*s);
        }
    }

    /// Drop all pinned strategies (back to lane-driven dispatch).
    pub fn clear_strategies(&mut self) {
        for lw in &mut self.layers {
            lw.strategy = None;
        }
    }

    /// The pinned per-layer strategies, in layer order.
    pub fn strategies(&self) -> Vec<Option<ExecStrategy>> {
        self.layers.iter().map(|l| l.strategy).collect()
    }

    /// Pin per-layer backward strategies (the backward tuner's winners,
    /// in layer order).  Panics on a length mismatch.
    pub fn set_backward_strategies(&mut self, strategies: &[ExecStrategy]) {
        assert_eq!(
            strategies.len(),
            self.layers.len(),
            "one backward strategy per layer"
        );
        for (lw, s) in self.layers.iter_mut().zip(strategies) {
            lw.backward_strategy = Some(*s);
        }
    }

    /// The pinned per-layer backward strategies, in layer order.
    pub fn backward_strategies(&self) -> Vec<Option<ExecStrategy>> {
        self.layers.iter().map(|l| l.backward_strategy).collect()
    }

    /// Arena sized for the largest layer of this generator, honoring
    /// each layer's pinned strategy: only layers pinned to the
    /// PhaseGemm formulation claim the im2col patch region, so
    /// direct-only generators stay at the direct sizing.  (The arena
    /// still grows on demand if strategies are re-pinned afterwards.)
    pub fn scratch(&self) -> Scratch {
        Scratch::with_floats(self.max_scratch_floats())
    }

    /// Exact per-arena float requirement (max over the layers, per
    /// pinned strategy).
    pub fn max_scratch_floats(&self) -> usize {
        self.layers
            .iter()
            .map(LayerWeights::scratch_floats)
            .max()
            .unwrap_or(0)
    }

    /// Arena sized for fused batched execution at batch size `n`
    /// under `lane` (DESIGN.md §Batched-Execution) — the batched
    /// analogue of [`scratch`](Self::scratch).
    pub fn scratch_batch(&self, n: usize, lane: Lane) -> Scratch {
        Scratch::with_floats(self.max_scratch_floats_batch(n, lane))
    }

    /// Exact per-arena float requirement for batched execution at
    /// batch size `n` under `lane` (max over the layers, per pinned
    /// strategy).
    pub fn max_scratch_floats_batch(&self, n: usize, lane: Lane) -> usize {
        self.layers
            .iter()
            .map(|lw| lw.scratch_floats_batch(n, lane))
            .max()
            .unwrap_or(0)
    }

    /// Full forward pass: latent → image, with the chosen conv backend.
    /// Allocates a fresh arena — steady-state callers (the serving
    /// backend, the benches) should hold one and use
    /// [`forward_with`](Self::forward_with).
    pub fn forward(&self, z: &[f32], alg: Algorithm, lane: Lane) -> Feature {
        let mut scratch = self.scratch();
        self.forward_with(z, alg, lane, &mut scratch)
    }

    /// Full forward pass threading one scratch arena through all layers.
    pub fn forward_with(
        &self,
        z: &[f32],
        alg: Algorithm,
        lane: Lane,
        scratch: &mut Scratch,
    ) -> Feature {
        let _span = trace::span("gen.forward", "model", trace::NONE, trace::NONE);
        let mut x = self.project(z);
        let last = self.layers.len() - 1;
        for (i, lw) in self.layers.iter().enumerate() {
            // Layer numbers follow Table 4 (the projection is layer 1).
            // The bias+activation epilogue belongs to the layer — a
            // pinned fused-epilogue strategy applies it in-register
            // inside `apply_act` (DESIGN.md §Fused-Epilogue).
            let _layer_span =
                trace::span("layer.forward", lw.lane_tag(), (i + 2) as u32, trace::NONE);
            x = lw.apply_act(&x, alg, lane, i == last, scratch);
        }
        x
    }

    /// Fused batched forward pass (DESIGN.md §Batched-Execution):
    /// latents → one [`FeatureBatch`] of images through the unified
    /// planned path, each layer executing the **whole** micro-batch in
    /// one call ([`LayerWeights::apply_batch`]) with batched
    /// bias+activation epilogues.  Allocates a fresh arena —
    /// steady-state callers use
    /// [`forward_batch_with`](Self::forward_batch_with).
    pub fn forward_batch(&self, latents: &[Vec<f32>], lane: Lane) -> FeatureBatch {
        let mut scratch = self.scratch_batch(latents.len(), lane);
        self.forward_batch_with(latents, lane, &mut scratch)
    }

    /// [`forward_batch`](Self::forward_batch) threading one scratch
    /// arena through all layers.  Per image, the arithmetic is exactly
    /// the single-image [`forward_with`](Self::forward_with)'s — same
    /// projection, same conv cores, same epilogues — so the batched
    /// forward is bit-identical to `N` sequential forwards on the
    /// direct lanes and within 1e-4 on pinned GEMM lanes.
    pub fn forward_batch_with(
        &self,
        latents: &[Vec<f32>],
        lane: Lane,
        scratch: &mut Scratch,
    ) -> FeatureBatch {
        let _span = trace::span("gen.forward_batch", "model", trace::NONE, trace::NONE);
        let spec0 = self.layers[0].spec;
        let (n0, c0) = (spec0.n_in, spec0.cin);
        let n = latents.len();
        let mut x = FeatureBatch::zeros(n, n0, n0, c0);
        for (i, z) in latents.iter().enumerate() {
            let f = self.project(z);
            x.image_mut(i).copy_from_slice(&f.data);
        }
        let last = self.layers.len() - 1;
        for (i, lw) in self.layers.iter().enumerate() {
            let mut y = lw.plan.new_batch_output(n);
            {
                let _layer_span =
                    trace::span("layer.forward", lw.lane_tag(), (i + 2) as u32, trace::NONE);
                lw.apply_batch_act(&x, lane, i == last, scratch, &mut y);
            }
            x = y;
        }
        x
    }

    /// Full forward pass on the unplanned per-call path (ablation lane
    /// for planned-vs-unplanned A/B serving).
    pub fn forward_unplanned(&self, z: &[f32], alg: Algorithm, lane: Lane) -> Feature {
        let mut x = self.project(z);
        let last = self.layers.len() - 1;
        for (i, lw) in self.layers.iter().enumerate() {
            x = lw.apply_unplanned(&x, alg, lane);
            ops::add_bias_inplace(&mut x, &lw.bias);
            if i == last {
                ops::tanh_inplace(&mut x);
            } else {
                ops::relu_inplace(&mut x);
            }
        }
        x
    }

    /// Forward pass through the transpose-conv layers only, from a given
    /// first feature map — exactly what Table 4 times ("computation time
    /// ... only for the forward propagation stage for the transpose
    /// convolution layers").
    pub fn forward_conv_only(&self, x0: &Feature, alg: Algorithm, lane: Lane) -> Feature {
        let mut scratch = self.scratch();
        self.forward_conv_only_with(x0, alg, lane, &mut scratch)
    }

    /// Conv-only forward threading one scratch arena through all layers.
    pub fn forward_conv_only_with(
        &self,
        x0: &Feature,
        alg: Algorithm,
        lane: Lane,
        scratch: &mut Scratch,
    ) -> Feature {
        let mut x = x0.clone();
        for lw in &self.layers {
            x = lw.apply(&x, alg, lane, scratch);
        }
        x
    }

    /// Expected output shape `(H, W, C)`.
    pub fn output_shape(&self) -> (usize, usize, usize) {
        let last = self.layers.last().unwrap().spec;
        (last.n_out(), last.n_out(), last.cout)
    }

    /// Total weight bytes (projection + kernels + biases).
    pub fn weight_bytes(&self) -> usize {
        let f32s = std::mem::size_of::<f32>();
        (self.proj_w.len() + self.proj_b.len()) * f32s
            + self
                .layers
                .iter()
                .map(|l| l.kernel.bytes() + l.bias.len() * f32s)
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::max_abs_diff;

    fn tiny_generator() -> Generator {
        // Shrink DC-GAN channels for fast tests by building a custom
        // Generator directly.
        let mut rng = Rng::seeded(60);
        let mut g = Generator::random(GanModel::GpGan, &mut rng);
        // Truncate to the first two layers and shrink channels via a
        // fresh random build of just those specs.
        let specs = [LayerSpec::gan(4, 8, 6), LayerSpec::gan(8, 6, 3)];
        g.layers = specs
            .iter()
            .map(|&spec| {
                let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
                LayerWeights::new(spec, kernel, vec![0.01; spec.cout])
            })
            .collect();
        let z = g.model.z_dim();
        let out0 = 4 * 4 * 8;
        g.proj_w = vec![0.02; z * out0];
        g.proj_b = vec![0.0; out0];
        g
    }

    #[test]
    fn forward_shape_and_range() {
        let g = tiny_generator();
        let mut rng = Rng::seeded(61);
        let z: Vec<f32> = (0..g.model.z_dim()).map(|_| rng.normal_f32()).collect();
        let img = g.forward(&z, Algorithm::Unified, Lane::Serial);
        assert_eq!((img.h, img.w, img.c), (16, 16, 3));
        assert!(img.data.iter().all(|v| v.abs() <= 1.0)); // tanh range
    }

    #[test]
    fn algorithms_agree_through_full_model() {
        let g = tiny_generator();
        let mut rng = Rng::seeded(62);
        let z: Vec<f32> = (0..g.model.z_dim()).map(|_| rng.normal_f32()).collect();
        let want = g.forward(&z, Algorithm::Conventional, Lane::Serial);
        for alg in [Algorithm::Grouped, Algorithm::Unified, Algorithm::Im2col] {
            let got = g.forward(&z, alg, Lane::Serial);
            assert!(
                max_abs_diff(&want, &got) < 1e-3,
                "{} disagrees through the generator",
                alg.name()
            );
        }
        let par = g.forward(&z, Algorithm::Unified, Lane::Parallel(4));
        assert!(max_abs_diff(&want, &par) < 1e-3);
    }

    #[test]
    fn planned_equals_unplanned_through_full_model() {
        // The planned path must be bit-identical to the per-call unified
        // dispatch — same slabs, same loops, same accumulation order.
        let g = tiny_generator();
        let z = vec![0.2; g.model.z_dim()];
        for lane in [Lane::Serial, Lane::Parallel(3)] {
            let planned = g.forward(&z, Algorithm::Unified, lane);
            let unplanned = g.forward_unplanned(&z, Algorithm::Unified, lane);
            assert_eq!(planned, unplanned);
        }
    }

    #[test]
    fn shared_arena_reused_across_calls() {
        let g = tiny_generator();
        let z = vec![0.1; g.model.z_dim()];
        let want = g.forward(&z, Algorithm::Unified, Lane::Serial);
        let mut scratch = g.scratch();
        assert_eq!(scratch.capacity_floats(), g.max_scratch_floats());
        for _ in 0..3 {
            let got = g.forward_with(&z, Algorithm::Unified, Lane::Serial, &mut scratch);
            assert_eq!(got, want);
        }
        // The arena never grows past the precomputed exact requirement.
        assert_eq!(scratch.capacity_floats(), g.max_scratch_floats());
    }

    #[test]
    fn pinned_strategies_bit_identical_and_clearable() {
        // Any mix of tuned strategies must reproduce the default
        // unified forward exactly, whatever lane the caller asks for.
        use crate::tune::space::{ExecStrategy, ParAxis};
        let mut g = tiny_generator();
        let z = vec![0.15; g.model.z_dim()];
        let want = g.forward(&z, Algorithm::Unified, Lane::Serial);
        g.set_strategies(&[
            ExecStrategy::serial_per_element(),
            ExecStrategy::parallel(3, ParAxis::Rows),
        ]);
        assert!(g.strategies().iter().all(Option::is_some));
        for lane in [Lane::Serial, Lane::Parallel(2)] {
            let got = g.forward(&z, Algorithm::Unified, lane);
            assert_eq!(got, want, "pinned strategies diverged on {}", lane.name());
        }
        // Non-unified algorithms ignore the pins entirely.
        let conv = g.forward(&z, Algorithm::Conventional, Lane::Serial);
        assert!(max_abs_diff(&conv, &want) < 1e-3);
        g.clear_strategies();
        assert!(g.strategies().iter().all(Option::is_none));
        assert_eq!(g.forward(&z, Algorithm::Unified, Lane::Serial), want);
    }

    #[test]
    fn arena_sizing_tracks_pinned_strategies() {
        // Direct-only generators must not pay for the GEMM patch
        // region; pinning a PhaseGemm strategy grows the requirement
        // to that layer's full figure, and clearing restores it.
        use crate::tune::space::ExecStrategy;
        let mut g = tiny_generator();
        let direct = g.max_scratch_floats();
        assert_eq!(
            direct,
            g.layers
                .iter()
                .map(|l| l.plan.scratch_floats_direct())
                .max()
                .unwrap()
        );
        g.set_strategies(&[ExecStrategy::serial_gemm(), ExecStrategy::serial()]);
        let with_gemm = g.max_scratch_floats();
        assert_eq!(
            with_gemm,
            g.layers[0]
                .plan
                .scratch_floats()
                .max(g.layers[1].plan.scratch_floats_direct())
        );
        assert!(with_gemm >= direct);
        assert_eq!(g.scratch().capacity_floats(), with_gemm);
        g.clear_strategies();
        assert_eq!(g.max_scratch_floats(), direct);
    }

    #[test]
    fn pinned_gemm_strategy_matches_within_tolerance() {
        // A tuner verdict may pin the PhaseGemm formulation on a layer
        // (ISSUE 4): the forward pass must match the direct reference
        // within the 1e-4 reassociation tolerance — serial and
        // row-parallel GEMM lanes alike.
        use crate::tune::space::ExecStrategy;
        let mut g = tiny_generator();
        let z = vec![0.12; g.model.z_dim()];
        let want = g.forward(&z, Algorithm::Unified, Lane::Serial);
        for pins in [
            [ExecStrategy::serial_gemm(), ExecStrategy::serial_gemm()],
            [ExecStrategy::gemm_parallel(3), ExecStrategy::serial()],
        ] {
            g.set_strategies(&pins);
            let got = g.forward(&z, Algorithm::Unified, Lane::Serial);
            assert!(
                max_abs_diff(&got, &want) < 1e-4,
                "pinned GEMM strategies diverged"
            );
        }
        g.clear_strategies();
    }

    #[test]
    fn forward_batch_bit_identical_to_sequential_forwards() {
        // ISSUE 5 acceptance: forward_batch == N sequential forwards,
        // bit-identically on direct lanes — ragged batch sizes included.
        let g = tiny_generator();
        let mut rng = Rng::seeded(64);
        for n in [1usize, 3, 8] {
            let latents: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..g.model.z_dim()).map(|_| rng.normal_f32()).collect())
                .collect();
            for lane in [Lane::Serial, Lane::Parallel(3)] {
                let batched = g.forward_batch(&latents, lane);
                assert_eq!((batched.n, batched.h, batched.w, batched.c), (n, 16, 16, 3));
                for (i, z) in latents.iter().enumerate() {
                    let want = g.forward(z, Algorithm::Unified, lane);
                    assert_eq!(
                        batched.image(i),
                        &want.data[..],
                        "image {i} diverged (n={n}, {})",
                        lane.name()
                    );
                }
            }
        }
    }

    #[test]
    fn forward_batch_with_pinned_strategies() {
        // Pinned fused GEMM strategies: within the 1e-4 reassociation
        // tolerance of the direct forward.  Pinned non-fused strategies
        // take the per-latent loop and stay bit-identical.
        use crate::tune::space::ExecStrategy;
        let mut g = tiny_generator();
        let latents: Vec<Vec<f32>> = (0..3)
            .map(|i| vec![0.05 * (i + 1) as f32; g.model.z_dim()])
            .collect();
        let want: Vec<Feature> = latents
            .iter()
            .map(|z| g.forward(z, Algorithm::Unified, Lane::Serial))
            .collect();
        g.set_strategies(&[
            ExecStrategy::serial_gemm().fused(),
            ExecStrategy::gemm_parallel(2).fused(),
        ]);
        let fused = g.forward_batch(&latents, Lane::Serial);
        for (i, w) in want.iter().enumerate() {
            let img = Feature::from_vec(16, 16, 3, fused.image(i).to_vec());
            assert!(
                max_abs_diff(&img, w) < 1e-4,
                "fused GEMM batch diverged on image {i}"
            );
        }
        g.set_strategies(&[
            ExecStrategy::serial(),
            ExecStrategy::parallel(2, crate::tune::space::ParAxis::Rows),
        ]);
        let per_latent = g.forward_batch(&latents, Lane::Serial);
        for (i, w) in want.iter().enumerate() {
            assert_eq!(per_latent.image(i), &w.data[..], "per-latent pin diverged");
        }
        g.clear_strategies();
    }

    #[test]
    fn fused_epilogue_pins_match_reference_through_model() {
        // ISSUE 10: strategies carrying the fused-epilogue axis apply
        // bias + ReLU/tanh in-register inside the GEMM store and must
        // match the conv-then-apply reference within the GEMM lanes'
        // 1e-4 contract — single-image and batched dispatch alike, and
        // the fused pin must never claim *more* scratch than its
        // separate twin.
        use crate::tune::space::ExecStrategy;
        let mut g = tiny_generator();
        let z = vec![0.12; g.model.z_dim()];
        let want = g.forward(&z, Algorithm::Unified, Lane::Serial);
        g.set_strategies(&[
            ExecStrategy::serial_gemm().fused_epilogue(),
            ExecStrategy::gemm_parallel(2).fused_epilogue(),
        ]);
        let got = g.forward(&z, Algorithm::Unified, Lane::Serial);
        assert!(
            max_abs_diff(&got, &want) < 1e-4,
            "fused-epilogue pins diverged through the generator"
        );
        for (lw, sep) in g.layers.iter().zip([
            ExecStrategy::serial_gemm(),
            ExecStrategy::gemm_parallel(2),
        ]) {
            assert!(lw.scratch_floats() < lw.plan.scratch_floats_for(&sep));
        }
        // Batched: fused-epilogue on the stacked batched GEMM.
        let latents: Vec<Vec<f32>> = (0..3)
            .map(|i| vec![0.03 * (i + 1) as f32; g.model.z_dim()])
            .collect();
        g.set_strategies(&[
            ExecStrategy::serial_gemm().fused().fused_epilogue(),
            ExecStrategy::gemm_parallel(2).fused().fused_epilogue(),
        ]);
        let fb = g.forward_batch(&latents, Lane::Serial);
        g.clear_strategies();
        for (i, zi) in latents.iter().enumerate() {
            let w = g.forward(zi, Algorithm::Unified, Lane::Serial);
            let img = Feature::from_vec(16, 16, 3, fb.image(i).to_vec());
            assert!(
                max_abs_diff(&img, &w) < 1e-4,
                "batched fused-epilogue diverged on image {i}"
            );
        }
    }

    #[test]
    fn batched_arena_sizing_tracks_strategies_and_lane() {
        use crate::tune::space::ExecStrategy;
        let mut g = tiny_generator();
        let n = 4;
        // Lane-driven parallel dispatch goes image-parallel: n× direct;
        // the serial lane loops one direct region and must not pay n×.
        assert_eq!(
            g.max_scratch_floats_batch(n, Lane::Parallel(2)),
            g.layers
                .iter()
                .map(|l| l.plan.scratch_floats_batch_par(n))
                .max()
                .unwrap()
        );
        assert_eq!(
            g.max_scratch_floats_batch(n, Lane::Serial),
            g.layers
                .iter()
                .map(|l| l.plan.scratch_floats_direct())
                .max()
                .unwrap()
        );
        // A fused GEMM pin claims the stacked patch/phase regions
        // (lane irrelevant once strategies are pinned).
        g.set_strategies(&[
            ExecStrategy::serial_gemm().fused(),
            ExecStrategy::serial(),
        ]);
        assert_eq!(
            g.max_scratch_floats_batch(n, Lane::Serial),
            g.layers[0]
                .plan
                .scratch_floats_gemm_batch(n)
                .max(g.layers[1].plan.scratch_floats_direct())
        );
        assert_eq!(
            g.scratch_batch(n, Lane::Serial).capacity_floats(),
            g.max_scratch_floats_batch(n, Lane::Serial)
        );
        // The batched forward never outgrows the precomputed figure.
        let latents: Vec<Vec<f32>> = (0..n).map(|_| vec![0.1; g.model.z_dim()]).collect();
        let mut scratch = g.scratch_batch(n, Lane::Serial);
        let _ = g.forward_batch_with(&latents, Lane::Serial, &mut scratch);
        assert_eq!(
            scratch.capacity_floats(),
            g.max_scratch_floats_batch(n, Lane::Serial)
        );
        g.clear_strategies();
    }

    #[test]
    fn layer_backward_matches_one_shot_unified_grads() {
        // `backward_with` = activation gate (from the post-activation
        // map) → bias spatial sum → planned data-grad + weight-grad.
        // Pin it against a hand-rolled gate feeding the one-shot
        // unified reference, for both activations and for a pinned
        // GEMM backward lane.
        use crate::conv::backward::{grad_input_unified, grad_kernel_unified};
        let g = tiny_generator();
        let mut rng = Rng::seeded(65);
        for (li, last) in [(0usize, false), (1usize, true)] {
            let lw = &g.layers[li];
            let spec = lw.spec;
            let x = Feature::random(spec.n_in, spec.n_in, spec.cin, &mut rng);
            let mut scratch = Scratch::with_floats(
                lw.scratch_floats().max(lw.scratch_floats_backward()),
            );
            let mut y = lw.apply(&x, Algorithm::Unified, Lane::Serial, &mut scratch);
            ops::add_bias_inplace(&mut y, &lw.bias);
            if last {
                ops::tanh_inplace(&mut y);
            } else {
                ops::relu_inplace(&mut y);
            }
            let dy = Feature::random(y.h, y.w, y.c, &mut rng);
            let (dx, dk, db) = lw.backward_with(&x, &y, &dy, last, &mut scratch);
            // Hand-rolled activation gate.
            let mut dpre = dy.clone();
            if last {
                for (d, &yv) in dpre.data.iter_mut().zip(&y.data) {
                    *d *= 1.0 - yv * yv;
                }
            } else {
                for (d, &yv) in dpre.data.iter_mut().zip(&y.data) {
                    if yv <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            let want_dx = grad_input_unified(&dpre, &lw.kernel, spec.n_in, spec.padding);
            let want_dk = grad_kernel_unified(&x, &dpre, spec.ksize, spec.padding);
            // Unpinned backward runs the direct lane: bit-identical dx.
            assert_eq!(dx, want_dx, "layer {li} dx diverged");
            let dk_err = dk
                .data
                .iter()
                .zip(&want_dk.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(dk_err < 1e-4, "layer {li} dk err {dk_err}");
            let want_db: Vec<f32> = (0..spec.cout)
                .map(|c| {
                    dpre.data
                        .iter()
                        .skip(c)
                        .step_by(spec.cout)
                        .sum::<f32>()
                })
                .collect();
            for (a, b) in db.iter().zip(&want_db) {
                assert!((a - b).abs() < 1e-4, "db diverged");
            }
            // A pinned GEMM backward lane stays within the 1e-4
            // reassociation contract.
            let pinned = lw.clone().with_backward_strategy(ExecStrategy::serial_gemm());
            assert!(pinned.scratch_floats_backward() >= lw.scratch_floats_backward());
            let mut scratch2 = Scratch::with_floats(pinned.scratch_floats_backward());
            let (dx2, _, _) = pinned.backward_with(&x, &y, &dy, last, &mut scratch2);
            let dx_err = dx2
                .data
                .iter()
                .zip(&want_dx.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(dx_err < 1e-4, "pinned GEMM dx err {dx_err}");
        }
    }

    #[test]
    fn backward_strategy_pins_settable_and_listable() {
        use crate::tune::space::ParAxis;
        let mut g = tiny_generator();
        assert!(g.backward_strategies().iter().all(Option::is_none));
        g.set_backward_strategies(&[
            ExecStrategy::serial_gemm(),
            ExecStrategy::parallel(2, ParAxis::PhaseRows),
        ]);
        assert!(g.backward_strategies().iter().all(Option::is_some));
        assert_eq!(g.backward_strategies()[0], Some(ExecStrategy::serial_gemm()));
    }

    #[test]
    fn deterministic() {
        let g = tiny_generator();
        let z = vec![0.1; g.model.z_dim()];
        let a = g.forward(&z, Algorithm::Unified, Lane::Serial);
        let b = g.forward(&z, Algorithm::Unified, Lane::Serial);
        assert_eq!(a, b);
    }

    #[test]
    fn conv_only_matches_table4_protocol() {
        let g = tiny_generator();
        let mut rng = Rng::seeded(63);
        let x0 = Feature::random(4, 4, 8, &mut rng);
        let a = g.forward_conv_only(&x0, Algorithm::Conventional, Lane::Serial);
        let b = g.forward_conv_only(&x0, Algorithm::Unified, Lane::Serial);
        assert_eq!((a.h, a.w, a.c), (16, 16, 3));
        assert!(max_abs_diff(&a, &b) < 1e-3);
    }

    #[test]
    fn weight_bytes_positive() {
        let g = tiny_generator();
        assert!(g.weight_bytes() > 0);
    }
}
