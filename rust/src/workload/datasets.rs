//! Table 1: dataset characteristics, and synthetic image synthesis.
//!
//! The paper converts every image to `224×224×3` and applies the
//! transpose convolution to each sample; computation cost is fully
//! determined by (shape, count), so synthetic tensors with the *exact*
//! Table 1 sample counts reproduce the workload (DESIGN.md §2).

use crate::tensor::Feature;
use crate::util::rng::Rng;

/// The paper's standard image size after conversion.
pub const IMAGE_SIZE: usize = 224;
pub const IMAGE_CHANNELS: usize = 3;

/// One dataset group (a Table 1 / Table 2 row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatasetGroup {
    /// Parent dataset name.
    pub dataset: &'static str,
    /// Group/split name as the tables print it.
    pub group: &'static str,
    /// Exact sample count from Table 1.
    pub samples: usize,
}

/// Table 1, transcribed verbatim.
pub const FLOWER_GROUPS: [DatasetGroup; 5] = [
    DatasetGroup {
        dataset: "Flowers",
        group: "Daisy",
        samples: 769,
    },
    DatasetGroup {
        dataset: "Flowers",
        group: "Dandelion",
        samples: 1052,
    },
    DatasetGroup {
        dataset: "Flowers",
        group: "Rose",
        samples: 784,
    },
    DatasetGroup {
        dataset: "Flowers",
        group: "Sunflower",
        samples: 734,
    },
    DatasetGroup {
        dataset: "Flowers",
        group: "Tulip",
        samples: 984,
    },
];

/// Table 3's rows (MSCOCO 2017 at the paper's 10% subset; PASCAL VOC
/// 2012 classification + segmentation splits).
pub const TABLE3_GROUPS: [DatasetGroup; 3] = [
    DatasetGroup {
        dataset: "MSCOCO 2017",
        group: "(10% subset)",
        samples: 11_828,
    },
    DatasetGroup {
        dataset: "PASCAL VOC 2012",
        group: "Classification",
        samples: 17_125,
    },
    DatasetGroup {
        dataset: "PASCAL VOC 2012",
        group: "Segmentation",
        samples: 2_913,
    },
];

impl DatasetGroup {
    /// Synthesize one sample (contents are irrelevant to timing; a
    /// per-dataset seed keeps runs reproducible).
    pub fn sample(&self, index: usize, size: usize) -> Feature {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a over the identity
        for b in self
            .dataset
            .bytes()
            .chain(self.group.bytes())
            .chain(index.to_le_bytes())
        {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut rng = Rng::seeded(h);
        Feature::random(size, size, IMAGE_CHANNELS, &mut rng)
    }

    /// Standard-size sample (224×224×3).
    pub fn standard_sample(&self, index: usize) -> Feature {
        self.sample(index, IMAGE_SIZE)
    }
}

/// Table 1 as printable rows: (dataset, group, samples).
pub fn table1_rows() -> Vec<(&'static str, &'static str, usize)> {
    FLOWER_GROUPS
        .iter()
        .chain(TABLE3_GROUPS.iter())
        .map(|g| (g.dataset, g.group, g.samples))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_verbatim() {
        let total_flowers: usize = FLOWER_GROUPS.iter().map(|g| g.samples).sum();
        assert_eq!(total_flowers, 769 + 1052 + 784 + 734 + 984);
        assert_eq!(TABLE3_GROUPS[0].samples, 11_828);
        assert_eq!(TABLE3_GROUPS[1].samples, 17_125);
        assert_eq!(TABLE3_GROUPS[2].samples, 2_913);
    }

    #[test]
    fn samples_deterministic_and_distinct() {
        let g = FLOWER_GROUPS[0];
        let a = g.sample(0, 16);
        let b = g.sample(0, 16);
        let c = g.sample(1, 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!((a.h, a.w, a.c), (16, 16, 3));
    }

    #[test]
    fn groups_have_distinct_streams() {
        let a = FLOWER_GROUPS[0].sample(0, 8);
        let b = FLOWER_GROUPS[1].sample(0, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn table1_rows_complete() {
        assert_eq!(table1_rows().len(), 8);
    }
}
