//! Serving-request generators for the coordinator's end-to-end driver.
//!
//! Open-loop (Poisson arrivals at a target rate) and closed-loop
//! (fixed concurrency) generators over the GAN image-generation
//! request type.

use crate::coordinator::request::GenRequest;
use crate::util::rng::Rng;

/// A request paired with its (relative) arrival time in seconds.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at: f64,
    pub request: GenRequest,
}

/// Open-loop Poisson trace: `count` requests at `rate` req/s targeting
/// `model`, each with a fresh random latent.
pub fn poisson_trace(
    model: &str,
    z_dim: usize,
    rate: f64,
    count: usize,
    rng: &mut Rng,
) -> Vec<TimedRequest> {
    let mut t = 0.0;
    (0..count)
        .map(|i| {
            t += rng.exponential(rate);
            let mut z = vec![0.0f32; z_dim];
            rng.fill_normal(&mut z);
            TimedRequest {
                at: t,
                request: GenRequest::new(i as u64, model.to_string(), z),
            }
        })
        .collect()
}

/// Uniform (deterministic-interval) trace at `rate` req/s.
pub fn uniform_trace(
    model: &str,
    z_dim: usize,
    rate: f64,
    count: usize,
    rng: &mut Rng,
) -> Vec<TimedRequest> {
    let dt = 1.0 / rate;
    (0..count)
        .map(|i| {
            let mut z = vec![0.0f32; z_dim];
            rng.fill_normal(&mut z);
            TimedRequest {
                at: dt * (i + 1) as f64,
                request: GenRequest::new(i as u64, model.to_string(), z),
            }
        })
        .collect()
}

/// Batch of ready-now requests (closed-loop building block).
pub fn burst(model: &str, z_dim: usize, count: usize, rng: &mut Rng) -> Vec<GenRequest> {
    (0..count)
        .map(|i| {
            let mut z = vec![0.0f32; z_dim];
            rng.fill_normal(&mut z);
            GenRequest::new(i as u64, model.to_string(), z)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrival_times_increase() {
        let mut rng = Rng::seeded(70);
        let trace = poisson_trace("dcgan", 100, 50.0, 200, &mut rng);
        assert_eq!(trace.len(), 200);
        for pair in trace.windows(2) {
            assert!(pair[1].at > pair[0].at);
        }
        // Mean inter-arrival ≈ 1/rate.
        let mean = trace.last().unwrap().at / 200.0;
        assert!((mean - 0.02).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn uniform_trace_spacing() {
        let mut rng = Rng::seeded(71);
        let trace = uniform_trace("dcgan", 10, 10.0, 5, &mut rng);
        assert!((trace[1].at - trace[0].at - 0.1).abs() < 1e-9);
    }

    #[test]
    fn burst_ids_unique() {
        let mut rng = Rng::seeded(72);
        let reqs = burst("dcgan", 10, 20, &mut rng);
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 20);
        assert_eq!(reqs[0].latent.len(), 10);
    }
}
