//! Workload substrate: the paper's datasets and serving-request
//! generators.
//!
//! * [`datasets`] — Table 1's dataset characteristics (exact sample
//!   counts) with synthetic image synthesis; the convolution is
//!   data-independent, so shape + count reproduce the timing workload
//! * [`generator`] — open/closed-loop request generators (Poisson
//!   arrivals) for the serving coordinator

pub mod datasets;
pub mod generator;
