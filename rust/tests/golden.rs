//! Cross-language golden tests: the Rust kernels must reproduce the JAX
//! oracle's numbers (artifacts/golden.json, emitted by `make artifacts`).
//!
//! This is the contract that makes the three-layer stack coherent: the
//! same (input, kernel, padding) triple produces the same output through
//! the pure-jnp oracle, the Pallas kernel (checked in pytest), and every
//! Rust algorithm (checked here).

use std::path::PathBuf;

use ukstc::conv::parallel::{run, Algorithm, Lane};
use ukstc::tensor::{Feature, Kernel};
use ukstc::util::json::{self, Json};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct GoldenCase {
    n_in: usize,
    n_k: usize,
    padding: usize,
    cin: usize,
    cout: usize,
    x: Feature,
    k: Kernel,
    out: Feature,
}

fn load_golden() -> Option<Vec<GoldenCase>> {
    let path = artifacts_dir().join("golden.json");
    if !path.exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    let v = json::parse_file(&path).expect("parse golden.json");
    let cases = v
        .get("cases")
        .and_then(Json::as_arr)
        .expect("golden cases")
        .iter()
        .map(|c| {
            let g = |k: &str| c.get(k).and_then(Json::as_usize).unwrap();
            let (n_in, n_k, padding, cin, cout) =
                (g("n_in"), g("n_k"), g("padding"), g("cin"), g("cout"));
            let out_shape = c.get("out_shape").and_then(Json::as_usize_vec).unwrap();
            GoldenCase {
                n_in,
                n_k,
                padding,
                cin,
                cout,
                x: Feature::from_vec(
                    n_in,
                    n_in,
                    cin,
                    c.get("x").and_then(Json::as_f32_vec).unwrap(),
                ),
                k: Kernel::from_vec(
                    n_k,
                    cin,
                    cout,
                    c.get("k").and_then(Json::as_f32_vec).unwrap(),
                ),
                out: Feature::from_vec(
                    out_shape[0],
                    out_shape[1],
                    out_shape[2],
                    c.get("out").and_then(Json::as_f32_vec).unwrap(),
                ),
            }
        })
        .collect();
    Some(cases)
}

fn check_algorithm(alg: Algorithm, lane: Lane) {
    let Some(cases) = load_golden() else { return };
    assert!(cases.len() >= 8, "golden set too small");
    for case in &cases {
        let got = run(alg, lane, &case.x, &case.k, case.padding);
        assert_eq!(
            (got.h, got.w, got.c),
            (case.out.h, case.out.w, case.out.c),
            "{} shape mismatch for N={} n={} P={}",
            alg.name(),
            case.n_in,
            case.n_k,
            case.padding
        );
        let err = ukstc::tensor::ops::max_abs_diff(&got, &case.out);
        assert!(
            err < 2e-3,
            "{} vs JAX oracle: max err {err} for N={} n={} P={} cin={} cout={}",
            alg.name(),
            case.n_in,
            case.n_k,
            case.padding,
            case.cin,
            case.cout
        );
    }
}

#[test]
fn conventional_matches_jax_oracle() {
    check_algorithm(Algorithm::Conventional, Lane::Serial);
}

#[test]
fn unified_matches_jax_oracle() {
    check_algorithm(Algorithm::Unified, Lane::Serial);
}

#[test]
fn unified_parallel_matches_jax_oracle() {
    check_algorithm(Algorithm::Unified, Lane::Parallel(4));
}

#[test]
fn grouped_matches_jax_oracle() {
    check_algorithm(Algorithm::Grouped, Lane::Serial);
}

#[test]
fn per_element_matches_jax_oracle() {
    check_algorithm(Algorithm::UnifiedPerElement, Lane::Serial);
}

#[test]
fn im2col_matches_jax_oracle() {
    check_algorithm(Algorithm::Im2col, Lane::Serial);
}
