//! Integration: the PJRT runtime executes the AOT Pallas artifacts and
//! agrees with the native Rust kernels — the full L1↔L3 round trip.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use ukstc::conv::parallel::{run, Algorithm, Lane};
use ukstc::coordinator::backend::Backend;
use ukstc::runtime::{Engine, PjrtBackend};
use ukstc::tensor::{Feature, Kernel};
use ukstc::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine_or_skip() -> Option<Engine> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime tests: run `make artifacts` first");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn unified_layer_artifact_matches_rust_kernel() {
    let Some(mut engine) = engine_or_skip() else { return };
    engine.compile("unified_layer_s8").unwrap();

    let mut rng = Rng::seeded(1234);
    let x = Feature::random(8, 8, 8, &mut rng);
    let k = Kernel::random(4, 8, 4, &mut rng);

    // PJRT path: the Pallas kernel lowered to HLO, batch dim of 1.
    let (data, shape) = engine
        .execute("unified_layer_s8", &[x.data.clone(), k.data.clone()])
        .unwrap();
    assert_eq!(shape, vec![1, 16, 16, 4]);
    let pjrt_out = Feature::from_vec(16, 16, 4, data);

    // Native path: the Rust unified kernel.
    let rust_out = run(Algorithm::Unified, Lane::Serial, &x, &k, 2);
    let err = ukstc::tensor::ops::max_abs_diff(&pjrt_out, &rust_out);
    assert!(err < 1e-3, "PJRT vs Rust unified kernel: max err {err}");
}

#[test]
fn conventional_and_unified_artifacts_agree() {
    let Some(mut engine) = engine_or_skip() else { return };
    engine.compile("unified_layer_s8").unwrap();
    engine.compile("conv_layer_s8").unwrap();

    let mut rng = Rng::seeded(5678);
    let x = Feature::random(8, 8, 8, &mut rng);
    let k = Kernel::random(4, 8, 4, &mut rng);
    let (a, _) = engine
        .execute("unified_layer_s8", &[x.data.clone(), k.data.clone()])
        .unwrap();
    let (b, _) = engine
        .execute("conv_layer_s8", &[x.data, k.data])
        .unwrap();
    let err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(err < 1e-3, "unified vs conventional artifacts: {err}");
}

#[test]
fn execute_validates_inputs() {
    let Some(mut engine) = engine_or_skip() else { return };
    engine.compile("unified_layer_s8").unwrap();
    // Wrong arity.
    assert!(engine.execute("unified_layer_s8", &[vec![0.0; 8]]).is_err());
    // Wrong element count.
    assert!(engine
        .execute("unified_layer_s8", &[vec![0.0; 7], vec![0.0; 512]])
        .is_err());
    // Unknown artifact.
    assert!(engine.execute("nope", &[]).is_err());
}

#[test]
fn dcgan_generator_artifact_serves() {
    let Some(mut engine) = engine_or_skip() else { return };
    engine.compile("dcgan_b1").unwrap();
    let engine = Arc::new(engine);
    let backend = PjrtBackend::new(Arc::clone(&engine), "dcgan_b1", 7).unwrap();
    assert_eq!(backend.model_name(), "dcgan");
    assert_eq!(backend.z_dim(), 100);
    assert_eq!(backend.max_batch(), 1);

    let mut rng = Rng::seeded(42);
    let mut z = vec![0.0f32; 100];
    rng.fill_normal(&mut z);
    let imgs = backend.generate(&[z.clone()]);
    assert_eq!(imgs.len(), 1);
    assert_eq!((imgs[0].h, imgs[0].w, imgs[0].c), (64, 64, 3));
    // tanh output range, and non-degenerate (not all zeros — an
    // all-zero image would indicate the error fallback fired).
    assert!(imgs[0].data.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    assert!(imgs[0].data.iter().any(|v| v.abs() > 1e-6));

    // Determinism across calls.
    let again = backend.generate(&[z]);
    assert_eq!(imgs[0], again[0]);
}
