//! Phase-geometry exact-cover suite: the unified algorithm's four
//! parity phases must tile the output feature map exactly once — no
//! gaps, no overlap and, critically for odd output sizes, **no
//! over-compute** past the boundary (the prior grouped approach's
//! headline flaw, paper §3.2 / Fig. 5).

use ukstc::conv::unified::{phase_geometries, transpose_conv};
use ukstc::conv::{conventional, flops, out_size, ConvTransposeParams};
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::util::rng::Rng;

/// Count how many phases write each output cell; every cell must be
/// written exactly once and no phase may extend past the output edge.
fn assert_exact_cover(n_in: usize, n_k: usize, p: usize) {
    let ho = out_size(n_in, n_k, p);
    let mut cover = vec![0u32; ho * ho];
    for g in phase_geometries(n_in, n_k, p) {
        for i in 0..g.n_rows {
            for j in 0..g.n_cols {
                let (y, x) = (g.rp + 2 * i, g.sp + 2 * j);
                assert!(
                    y < ho && x < ho,
                    "phase ({},{}) writes ({y},{x}) outside {ho}×{ho} \
                     (over-compute) for n={n_in} k={n_k} p={p}",
                    g.rp,
                    g.sp
                );
                cover[y * ho + x] += 1;
            }
        }
    }
    for (idx, &c) in cover.iter().enumerate() {
        assert_eq!(
            c,
            1,
            "output cell ({}, {}) covered {c} times for n={n_in} k={n_k} p={p}",
            idx / ho,
            idx % ho
        );
    }
}

#[test]
fn odd_outputs_covered_exactly_once() {
    // All of these produce odd output sizes — the case where the
    // grouped prior work computes extra elements and unified must not.
    for (n_in, n_k, p) in [(4, 5, 2), (3, 3, 1), (5, 3, 2), (6, 5, 2), (2, 3, 0), (1, 3, 2)] {
        let ho = out_size(n_in, n_k, p);
        assert_eq!(ho % 2, 1, "case n={n_in} k={n_k} p={p} should be odd");
        assert_exact_cover(n_in, n_k, p);
    }
}

#[test]
fn even_outputs_covered_exactly_once() {
    for (n_in, n_k, p) in [(4, 4, 2), (8, 4, 2), (6, 4, 0), (5, 4, 1)] {
        let ho = out_size(n_in, n_k, p);
        assert_eq!(ho % 2, 0, "case n={n_in} k={n_k} p={p} should be even");
        assert_exact_cover(n_in, n_k, p);
    }
}

#[test]
fn fig5_case_phase_extents_and_numerics() {
    // Fig. 5 worked example: N=4, n=5, P=2 → 7×7 output (odd).
    let (n_in, n_k, p) = (4, 5, 2);
    assert_eq!(out_size(n_in, n_k, p), 7);
    let geoms = phase_geometries(n_in, n_k, p);
    assert_eq!(geoms.len(), 4);
    // Exact per-phase extents: 4×4 + 4×3 + 3×4 + 3×3 = 49 = 7².
    let extent = |rp: usize, sp: usize| {
        let g = geoms.iter().find(|g| (g.rp, g.sp) == (rp, sp)).unwrap();
        (g.n_rows, g.n_cols)
    };
    assert_eq!(extent(0, 0), (4, 4));
    assert_eq!(extent(0, 1), (4, 3));
    assert_eq!(extent(1, 0), (3, 4));
    assert_eq!(extent(1, 1), (3, 3));
    let total: usize = geoms.iter().map(|g| g.n_rows * g.n_cols).sum();
    assert_eq!(total, 49, "phases must compute exactly ho² elements");

    // Cross-check against the conventional (Algorithm 1) oracle.
    let mut rng = Rng::seeded(0x0DD);
    let x = Feature::random(n_in, n_in, 3, &mut rng);
    let k = Kernel::random(n_k, 3, 2, &mut rng);
    let want = conventional::transpose_conv(&x, &k, p);
    let got = transpose_conv(&x, &k, p);
    assert_eq!((got.h, got.w, got.c), (7, 7, 2));
    assert!(ops::max_abs_diff(&want, &got) < 1e-4);
}

#[test]
fn phase_work_matches_flop_model() {
    // The geometric extents must agree with the analytic FLOP model:
    // per-phase elements × sub-kernel taps × cin × cout == flops::unified.
    for (n_in, n_k, p) in [(4, 5, 2), (4, 4, 2), (7, 5, 3), (3, 3, 1)] {
        let params = ConvTransposeParams::new(n_in, n_k, p, 2, 3);
        let ceil = n_k.div_ceil(2);
        let floor = n_k / 2;
        let counted: u64 = phase_geometries(n_in, n_k, p)
            .iter()
            .map(|g| {
                let (r, s) = (g.sub / 2, g.sub % 2);
                let kr = if r == 0 { ceil } else { floor };
                let ks = if s == 0 { ceil } else { floor };
                (g.n_rows * g.n_cols * kr * ks * params.cin * params.cout) as u64
            })
            .sum();
        assert_eq!(
            counted,
            flops::unified(&params),
            "n={n_in} k={n_k} p={p}"
        );
    }
}

#[test]
fn grouped_overcomputes_on_odd_unified_does_not() {
    // The contrast the paper draws: on odd outputs the grouped prior
    // work rounds the block grid up and wastes MACs; the unified phase
    // decomposition never exceeds the exact output element count.
    let odd = ConvTransposeParams::new(4, 5, 2, 2, 2); // ho = 7
    assert!(odd.odd_output());
    assert!(flops::grouped(&odd) > flops::unified(&odd));

    let even = ConvTransposeParams::new(4, 4, 2, 2, 2); // ho = 8
    assert!(!even.odd_output());
    assert_eq!(flops::grouped(&even), flops::unified(&even));
}
