//! End-to-end coordinator tests: router + batcher + worker pool under
//! concurrent load, including backpressure and A/B algorithm serving.

use std::sync::Arc;
use std::time::Duration;

use ukstc::conv::parallel::{Algorithm, Lane};
use ukstc::coordinator::backend::RustBackend;
use ukstc::coordinator::batcher::BatchPolicy;
use ukstc::coordinator::request::{GenRequest, SubmitError};
use ukstc::coordinator::Coordinator;
use ukstc::models::{forward::LayerWeights, zoo::LayerSpec, GanModel, Generator};
use ukstc::tensor::Kernel;
use ukstc::util::rng::Rng;
use ukstc::workload::generator::{burst, poisson_trace};

/// Millisecond-fast generator (GP-GAN head shrunk to toy channels).
fn tiny_generator(seed: u64) -> Generator {
    let mut rng = Rng::seeded(seed);
    let mut g = Generator::random(GanModel::GpGan, &mut rng);
    let specs = [LayerSpec::gan(4, 6, 4), LayerSpec::gan(8, 4, 3)];
    g.layers = specs
        .iter()
        .map(|&spec| {
            let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            LayerWeights::new(spec, kernel, vec![0.0; spec.cout])
        })
        .collect();
    let out0 = 4 * 4 * 6;
    g.proj_w = vec![0.01; g.model.z_dim() * out0];
    g.proj_b = vec![0.0; out0];
    g
}

fn tiny_backend(alg: Algorithm) -> Arc<RustBackend> {
    Arc::new(RustBackend::from_generator(
        tiny_generator(99),
        alg,
        Lane::Serial,
        8,
    ))
}

#[test]
fn serves_poisson_trace_with_batching() {
    let coord = Coordinator::builder()
        .queue_capacity(128)
        .workers_per_model(2)
        .batch_policy(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
        })
        .register(tiny_backend(Algorithm::Unified))
        .start()
        .unwrap();

    let mut rng = Rng::seeded(7);
    let trace = poisson_trace("gpgan", 100, 2000.0, 64, &mut rng);
    let mut rxs = Vec::new();
    for tr in trace {
        // Compressed-time replay: no sleeping, just slam the queue —
        // exercises batch formation under burst.
        rxs.push((tr.request.id, coord.submit_blocking(tr.request).unwrap()));
    }
    for (id, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!((resp.image.h, resp.image.w, resp.image.c), (16, 16, 3));
    }
    let snap = coord.metrics("gpgan").unwrap();
    assert_eq!(snap.completed, 64);
    assert!(
        snap.mean_batch_size > 1.5,
        "burst traffic should batch: mean={}",
        snap.mean_batch_size
    );
}

#[test]
fn backpressure_rejects_when_full() {
    // One slow-ish worker, tiny queue → non-blocking submits must
    // eventually see QueueFull.
    let coord = Coordinator::builder()
        .queue_capacity(2)
        .workers_per_model(1)
        .batch_policy(BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_millis(1),
        })
        .register(tiny_backend(Algorithm::UnifiedPerElement))
        .start()
        .unwrap();

    let mut rng = Rng::seeded(8);
    let reqs = burst("gpgan", 100, 64, &mut rng);
    let mut accepted = 0;
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for r in reqs {
        match coord.submit(r) {
            Ok(rx) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(SubmitError::QueueFull(_)) => rejected += 1,
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(accepted > 0);
    assert!(rejected > 0, "expected backpressure rejections");
    for rx in rxs {
        let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let snap = coord.metrics("gpgan").unwrap();
    assert_eq!(snap.completed, accepted as u64);
    assert_eq!(snap.rejected, rejected as u64);
}

#[test]
fn routes_between_two_models() {
    // Same tiny architecture served under two algorithm backends with
    // different model names via distinct GanModel wrappers is not
    // possible (name comes from the zoo), so we check routing by model
    // name with one real + one unknown.
    let coord = Coordinator::builder()
        .register(tiny_backend(Algorithm::Unified))
        .start()
        .unwrap();
    assert_eq!(coord.models(), vec!["gpgan"]);
    let ok = coord.submit(GenRequest::new(0, "gpgan".into(), vec![0.0; 100]));
    assert!(ok.is_ok());
    let bad = coord.submit(GenRequest::new(1, "biggan".into(), vec![0.0; 100]));
    assert!(matches!(bad, Err(SubmitError::UnknownModel(_))));
}

#[test]
fn ab_serving_unified_vs_conventional_same_numerics() {
    // A/B: two coordinators, same weights, different kernels — the
    // service must be bit-compatible from the client's point of view.
    let run = |alg: Algorithm| {
        let coord = Coordinator::builder()
            .register(Arc::new(RustBackend::from_generator(
                tiny_generator(123),
                alg,
                Lane::Serial,
                4,
            )))
            .start()
            .unwrap();
        let req = GenRequest::new(0, "gpgan".into(), vec![0.25; 100]);
        let rx = coord.submit(req).unwrap();
        rx.recv_timeout(Duration::from_secs(30)).unwrap().image
    };
    let a = run(Algorithm::Unified);
    let b = run(Algorithm::Conventional);
    assert!(ukstc::tensor::ops::max_abs_diff(&a, &b) < 1e-3);
}
