//! Batched-execution contracts end to end (ISSUE 5, DESIGN.md
//! §Batched-Execution):
//!
//! * `Generator::forward_batch` equals `N` sequential `forward` calls
//!   **bit-identically** on direct lanes and within 1e-4 on pinned
//!   GEMM lanes — including ragged tail batches (N = 1, 3 under
//!   `max_batch = 8`).
//! * `RustBackend::generate`'s fused batched lane serves exactly what
//!   the per-latent loop and the batch-worker fan-out lane serve.
//! * The coordinator exercises the fused lane under dynamic batching
//!   and records the observed batch-size distribution.

use std::sync::Arc;
use std::time::Duration;

use ukstc::conv::parallel::{Algorithm, Lane};
use ukstc::coordinator::backend::RustBackend;
use ukstc::coordinator::batcher::BatchPolicy;
use ukstc::coordinator::Coordinator;
use ukstc::models::forward::LayerWeights;
use ukstc::models::zoo::LayerSpec;
use ukstc::models::{GanModel, Generator};
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::tune::space::ExecStrategy;
use ukstc::util::rng::Rng;
use ukstc::workload::generator::burst;

/// A millisecond-fast two-layer generator (the coordinator-test shape).
fn tiny_generator(seed: u64) -> Generator {
    let mut rng = Rng::seeded(seed);
    let mut g = Generator::random(GanModel::GpGan, &mut rng);
    let specs = [LayerSpec::gan(4, 6, 4), LayerSpec::gan(8, 4, 3)];
    g.layers = specs
        .iter()
        .map(|&spec| {
            let kernel = Kernel::random(spec.ksize, spec.cin, spec.cout, &mut rng);
            LayerWeights::new(spec, kernel, vec![0.01; spec.cout])
        })
        .collect();
    let out0 = 4 * 4 * 6;
    g.proj_w = vec![0.01; g.model.z_dim() * out0];
    g.proj_b = vec![0.0; out0];
    g
}

fn latents(n: usize, z_dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|_| (0..z_dim).map(|_| rng.normal_f32()).collect())
        .collect()
}

#[test]
fn forward_batch_equals_sequential_forwards_ragged() {
    let g = tiny_generator(0xBA7C);
    for n in [1usize, 3, 8] {
        let zs = latents(n, g.model.z_dim(), 0xFEED ^ n as u64);
        for lane in [Lane::Serial, Lane::Parallel(3)] {
            let batched = g.forward_batch(&zs, lane);
            for (i, z) in zs.iter().enumerate() {
                let want = g.forward(z, Algorithm::Unified, lane);
                assert_eq!(
                    batched.image(i),
                    &want.data[..],
                    "direct lane diverged (n={n}, image {i})"
                );
            }
        }
    }
}

#[test]
fn forward_batch_gemm_lanes_within_tolerance() {
    let mut g = tiny_generator(0xBA7D);
    let zs = latents(3, g.model.z_dim(), 0xF00D);
    let want: Vec<Feature> = zs
        .iter()
        .map(|z| g.forward(z, Algorithm::Unified, Lane::Serial))
        .collect();
    for pins in [
        [ExecStrategy::serial_gemm().fused(), ExecStrategy::serial_gemm().fused()],
        [ExecStrategy::gemm_parallel(3).fused(), ExecStrategy::serial()],
    ] {
        g.set_strategies(&pins);
        let batched = g.forward_batch(&zs, Lane::Serial);
        for (i, w) in want.iter().enumerate() {
            let got = Feature::from_vec(w.h, w.w, w.c, batched.image(i).to_vec());
            assert!(
                ops::max_abs_diff(&got, w) < 1e-4,
                "pinned fused GEMM batch diverged (image {i})"
            );
        }
    }
}

#[test]
fn backend_fused_lane_matches_ab_lanes_on_ragged_batches() {
    let make = || {
        RustBackend::from_generator(tiny_generator(0xBA7E), Algorithm::Unified, Lane::Serial, 8)
    };
    let fused = make();
    let per_latent = make().with_per_latent();
    let fanout = make().with_batch_workers(3);
    assert!(fused.is_fused_batch());
    assert_eq!(fused.max_batch(), 8);
    use ukstc::coordinator::Backend;
    for n in [1usize, 3, 8] {
        let zs = latents(n, fused.z_dim(), 0xABC ^ n as u64);
        let a = fused.generate(&zs);
        let b = per_latent.generate(&zs);
        let c = fanout.generate(&zs);
        assert_eq!(a.len(), n);
        assert_eq!(a, b, "fused vs per-latent diverged at n={n}");
        assert_eq!(a, c, "fused vs batch-worker fan-out diverged at n={n}");
    }
}

#[test]
fn coordinator_exercises_fused_lane_and_batch_metrics() {
    // One worker + a burst bigger than max_batch forces multi-request
    // batches through the fused lane; the snapshot must expose the
    // observed batch-size distribution.
    let backend = Arc::new(RustBackend::from_generator(
        tiny_generator(0xBA7F),
        Algorithm::Unified,
        Lane::Serial,
        4,
    ));
    assert!(backend.is_fused_batch());
    let coord = Coordinator::builder()
        .queue_capacity(64)
        .workers_per_model(1)
        .batch_policy(BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(5),
        })
        .register(backend)
        .start()
        .unwrap();
    let mut rng = Rng::seeded(77);
    let reqs = burst("gpgan", 100, 12, &mut rng);
    let rxs: Vec<_> = reqs
        .into_iter()
        .map(|r| coord.submit_blocking(r).expect("submit"))
        .collect();
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert_eq!((resp.image.h, resp.image.w, resp.image.c), (16, 16, 3));
    }
    let snap = coord.metrics("gpgan").unwrap();
    assert_eq!(snap.completed, 12);
    assert!(snap.batches >= 3, "12 requests over max_batch 4");
    assert!(snap.mean_batch_size >= 1.0);
    assert!(snap.batch_p50 >= 1.0);
    assert!(snap.batch_p95 >= snap.batch_p50);
    assert!(snap.batch_p95 <= 4.0, "batch sizes bounded by max_batch");
    let summary = snap.summary();
    assert!(summary.contains("size mean"), "{summary}");
}
