//! SIMD microkernel equivalence at the plan level (DESIGN.md
//! §SIMD-Dispatch): every ISA lane the host supports must agree with
//! the forced-scalar lane — through the full planned phase-GEMM
//! pipeline (segregate → im2col → pack → tiled GEMM → scatter) — over
//! the whole geometry envelope: paddings 0–3, `Cout` below / at / past
//! the register tile width, odd and even grids.
//!
//! The scalar lane is the correctness reference: it is always
//! available ([`Isa::is_available`] for `Scalar` is unconditionally
//! true), and the vector lanes differ from it only by FMA contraction
//! and reduction reassociation inside the register tile, so the
//! agreement bound is the crate-wide 1e-4 GEMM tolerance.  The direct
//! (non-GEMM) formulations are *bit-identical* across hosts by
//! contract — their saxpy dispatch uses mul+add, never FMA — which the
//! direct reference check below exercises implicitly.

use ukstc::conv::plan::{ConvTransposePlan, Scratch};
use ukstc::conv::quant::Precision;
use ukstc::conv::simd::Isa;
use ukstc::conv::ConvTransposeParams;
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::tune::space::ExecStrategy;
use ukstc::util::rng::Rng;

#[test]
fn every_supported_lane_matches_scalar_across_geometry_envelope() {
    // Scalar is always a valid pin — the portable fallback every
    // dispatch can degrade to.
    assert!(Isa::Scalar.is_available());
    let mut rng = Rng::seeded(0x51D);
    let cin = 3;
    for n_in in [4usize, 5] {
        for padding in 0..=3usize {
            for cout in [1usize, 3, 8, 17] {
                let p = ConvTransposeParams::new(n_in, 4, padding, cin, cout);
                let k = Kernel::random(4, cin, cout, &mut rng);
                let plan = ConvTransposePlan::new(p, &k);
                let x = Feature::random(n_in, n_in, cin, &mut rng);
                let mut scratch = Scratch::with_floats(plan.scratch_floats());
                // Direct serial reference (the plan's bit-exact lane).
                let mut direct = plan.new_output();
                plan.run(&x, &mut scratch, &mut direct);
                // Forced-scalar GEMM: the correctness reference for the
                // microkernel axis.
                let scalar_pin = ExecStrategy::serial_gemm().with_isa(Isa::Scalar);
                let mut scalar = plan.new_output();
                plan.run_with(&scalar_pin, &x, &mut scratch, &mut scalar);
                let base_err = ops::max_abs_diff(&scalar, &direct);
                assert!(
                    base_err < 1e-4,
                    "scalar GEMM vs direct: {base_err} (n={n_in} p={padding} cout={cout})"
                );
                for isa in Isa::supported() {
                    for strategy in [
                        ExecStrategy::serial_gemm().with_isa(isa),
                        ExecStrategy::gemm_parallel(3).with_isa(isa),
                    ] {
                        let mut got = plan.new_output();
                        plan.run_with(&strategy, &x, &mut scratch, &mut got);
                        assert!(
                            got.data.iter().all(|v| v.is_finite()),
                            "{} produced non-finite output (n={n_in} p={padding} cout={cout})",
                            strategy.name()
                        );
                        let err = ops::max_abs_diff(&got, &scalar);
                        assert!(
                            err < 1e-4,
                            "{} vs forced scalar: {err} (n={n_in} p={padding} cout={cout})",
                            strategy.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn quantized_lanes_bounded_drift_across_geometry_envelope() {
    // DESIGN.md §Reduced-Precision: the quantized phase-GEMM lanes
    // store the packed B panel (and the im2col patch) at reduced
    // precision but accumulate in f32 (exact i32 for int8), so their
    // drift against the f32 lane is bounded by per-product operand
    // rounding summed over the ≤ cin·⌈k/2⌉² contributions per output
    // element.  The bound below is that triangle-inequality worst case
    // with a 2× margin — scale-aware (amax·kmax), not a magic epsilon,
    // so it stays meaningful across the whole geometry envelope.
    let mut rng = Rng::seeded(0x51D3);
    let cin = 3;
    for n_in in [4usize, 5] {
        for padding in 0..=3usize {
            for cout in [1usize, 3, 8, 17] {
                let p = ConvTransposeParams::new(n_in, 4, padding, cin, cout);
                let k = Kernel::random(4, cin, cout, &mut rng);
                let plan = ConvTransposePlan::new(p, &k);
                let x = Feature::random(n_in, n_in, cin, &mut rng);
                let mut scratch = Scratch::with_floats(plan.scratch_floats());
                let mut reference = plan.new_output();
                plan.run_with(&ExecStrategy::serial_gemm(), &x, &mut scratch, &mut reference);
                let amax = x.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let kmax = k.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                // ≤ cin·2·2 products per output element for a 4×4
                // kernel (each phase sub-kernel is 2×2), each term at
                // most amax·kmax in magnitude.
                let unit = (cin * 2 * 2) as f32 * amax * kmax;
                for (prec, coeff) in [
                    // f16: ≤ 2·2^-11 relative per product (both
                    // operands stored), bound 2^-9 = 2× margin.
                    (Precision::F16, 1.0 / 512.0),
                    // bf16: ≤ 2·2^-8 relative per product, 2× margin.
                    (Precision::Bf16, 1.0 / 64.0),
                    // int8: ≤ absmax/254 absolute per operand (symmetric
                    // absmax scale, round-to-nearest), ≈ amax·kmax/127
                    // per product, 2× margin.
                    (Precision::Int8, 1.0 / 64.0),
                ] {
                    let pinned = ExecStrategy::serial_gemm().with_precision(prec);
                    let mut got = plan.new_output();
                    plan.run_with(&pinned, &x, &mut scratch, &mut got);
                    assert!(
                        got.data.iter().all(|v| v.is_finite()),
                        "{} produced non-finite output (n={n_in} p={padding} cout={cout})",
                        pinned.name()
                    );
                    let err = ops::max_abs_diff(&got, &reference);
                    let bound = coeff * unit;
                    assert!(
                        err <= bound,
                        "{} vs f32: {err} > bound {bound} (n={n_in} p={padding} cout={cout})",
                        pinned.name()
                    );
                    // Cross-lane agreement of the same precision: the
                    // 16-bit lanes carry no scales and accumulate in a
                    // fixed k-order per output row, so the row-parallel
                    // dispatch is bit-identical to serial; int8 swaps
                    // per-phase for per-row patch scales, which moves
                    // the result only within the quantization bound.
                    let par = ExecStrategy::gemm_parallel(3).with_precision(prec);
                    let mut par_out = plan.new_output();
                    plan.run_with(&par, &x, &mut scratch, &mut par_out);
                    let par_err = ops::max_abs_diff(&par_out, &got);
                    if prec == Precision::Int8 {
                        assert!(
                            par_err <= 2.0 * bound,
                            "{} vs serial int8: {par_err} (n={n_in} p={padding} cout={cout})",
                            par.name()
                        );
                        // The int8 microkernel accumulates exactly in
                        // i32 (the AVX2 madd-pair lane widens every
                        // product before summing), so pinning any
                        // vector ISA is *bit-identical* to the forced
                        // scalar int8 lane — not just drift-bounded.
                        let scalar_int8 = ExecStrategy::serial_gemm()
                            .with_isa(Isa::Scalar)
                            .with_precision(prec);
                        let mut scalar_out = plan.new_output();
                        plan.run_with(&scalar_int8, &x, &mut scratch, &mut scalar_out);
                        assert_eq!(
                            scalar_out.data, got.data,
                            "int8 vector lane must be bit-identical to scalar \
                             (n={n_in} p={padding} cout={cout})"
                        );
                    } else {
                        assert_eq!(
                            par_err, 0.0,
                            "{} must be bit-identical to serial (n={n_in} p={padding} cout={cout})",
                            par.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn backward_lanes_match_scalar_across_isa_pins() {
    // The backward phase-GEMM lanes run the same microkernel dispatch;
    // pin each supported lane through the fused backward and compare
    // gradients against the forced-scalar pin.
    let mut rng = Rng::seeded(0x51D2);
    let (n_in, cin, cout) = (5usize, 3usize, 8usize);
    let p = ConvTransposeParams::new(n_in, 4, 2, cin, cout);
    let k = Kernel::random(4, cin, cout, &mut rng);
    let plan = ConvTransposePlan::new(p, &k);
    let ho = p.out_size();
    let x = Feature::random(n_in, n_in, cin, &mut rng);
    let dy = Feature::random(ho, ho, cout, &mut rng);
    let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
    let run = |isa: Isa, scratch: &mut Scratch| {
        let s = ExecStrategy::serial_gemm().with_isa(isa);
        let mut dx = plan.new_input_grad();
        let mut dk = plan.new_kernel_grad();
        plan.run_backward_with(&s, &x, &dy, scratch, &mut dx, &mut dk);
        (dx, dk)
    };
    let (dx_ref, dk_ref) = run(Isa::Scalar, &mut scratch);
    for isa in Isa::supported() {
        let (dx, dk) = run(isa, &mut scratch);
        let dx_err = ops::max_abs_diff(&dx, &dx_ref);
        assert!(dx_err < 1e-4, "{} dx vs scalar: {dx_err}", isa.name());
        let dk_err = dk
            .data
            .iter()
            .zip(&dk_ref.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(dk_err < 1e-4, "{} dk vs scalar: {dk_err}", isa.name());
    }
}
