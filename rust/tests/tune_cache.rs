//! Tuning-cache contract (ISSUE 3 satellites): the cache roundtrips
//! through its JSON file, and a cache hit performs **zero**
//! measurements — proven by injecting a counting measurer, exactly the
//! seam `tune::measure::Measurer` exists for.

use ukstc::conv::plan::ConvTransposePlan;
use ukstc::conv::ConvTransposeParams;
use ukstc::tensor::Kernel;
use ukstc::tune::measure::Measurer;
use ukstc::tune::space::{ExecStrategy, ParAxis};
use ukstc::tune::{Tuner, TuningCache};
use ukstc::util::rng::Rng;

/// Deterministic counting measurer: every call is tallied; the
/// 2-worker phase-rows strategy is scripted to win.
struct CountingMeasurer {
    calls: usize,
}

impl Measurer for CountingMeasurer {
    fn time_strategy(
        &mut self,
        _plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        _incumbent: Option<f64>,
    ) -> Option<f64> {
        self.calls += 1;
        Some(if *strategy == ExecStrategy::parallel(2, ParAxis::PhaseRows) {
            1.0
        } else {
            2.0 + self.calls as f64 * 1e-3
        })
    }
}

fn plan_for(n_in: usize, cin: usize, cout: usize) -> ConvTransposePlan {
    let mut rng = Rng::seeded(0xCAFE);
    let k = Kernel::random(4, cin, cout, &mut rng);
    ConvTransposePlan::new(ConvTransposeParams::new(n_in, 4, 2, cin, cout), &k)
}

#[test]
fn cache_hit_skips_measurement() {
    let plan = plan_for(4, 3, 2);
    let tuner = Tuner::new(2);
    let mut cache = TuningCache::in_memory();
    let mut measurer = CountingMeasurer { calls: 0 };

    let first = tuner.tune_layer_cached(&plan, &mut cache, &mut measurer);
    assert!(!first.cached);
    assert_eq!(
        measurer.calls,
        tuner.space.len(),
        "a miss measures the whole space"
    );
    assert_eq!(first.strategy, ExecStrategy::parallel(2, ParAxis::PhaseRows));
    assert_eq!(first.best_seconds, 1.0);

    let calls_after_first = measurer.calls;
    let second = tuner.tune_layer_cached(&plan, &mut cache, &mut measurer);
    assert!(second.cached);
    assert_eq!(
        measurer.calls, calls_after_first,
        "a cache hit must perform zero measurements"
    );
    assert_eq!(second.strategy, first.strategy);
    assert_eq!(second.best_seconds, first.best_seconds);
    assert!(second.candidates.is_empty());

    // A different layer shape is a miss again.
    tuner.tune_layer_cached(&plan_for(8, 2, 3), &mut cache, &mut measurer);
    assert_eq!(measurer.calls, calls_after_first + tuner.space.len());
    assert_eq!(cache.len(), 2);
}

#[test]
fn cache_roundtrips_through_json_file() {
    let dir = std::env::temp_dir().join(format!("ukstc-tune-cache-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.json");
    let _ = std::fs::remove_file(&path);

    let tuner = Tuner::new(3);
    {
        // A missing file is an empty, path-backed cache.
        let mut cache = TuningCache::load(&path).unwrap();
        assert!(cache.is_empty());
        assert_eq!(cache.path(), Some(path.as_path()));
        let mut measurer = CountingMeasurer { calls: 0 };
        tuner.tune_layer_cached(&plan_for(4, 3, 2), &mut cache, &mut measurer);
        assert_eq!(measurer.calls, tuner.space.len());
        cache.save().unwrap();
    }

    // A fresh process-equivalent load must serve the verdict with zero
    // measurements — tuning pays once per machine.
    let mut reloaded = TuningCache::load(&path).unwrap();
    assert_eq!(reloaded.len(), 1);
    let mut measurer = CountingMeasurer { calls: 0 };
    let verdict = tuner.tune_layer_cached(&plan_for(4, 3, 2), &mut reloaded, &mut measurer);
    assert!(verdict.cached);
    assert_eq!(measurer.calls, 0, "persisted cache must skip measurement");
    assert_eq!(verdict.strategy, ExecStrategy::parallel(2, ParAxis::PhaseRows));
    assert_eq!(verdict.best_seconds, 1.0);

    std::fs::remove_file(&path).ok();
}

#[test]
fn legacy_and_isa_fingerprint_entries_coexist_in_one_file() {
    // Migration contract (DESIGN.md §SIMD-Dispatch): cache keys are
    // opaque strings, so one version-1 file can simultaneously hold
    //   * legacy scalar-host entries   (`...@cpu{n}w{k}`),
    //   * batched entries              (`...w{k}b{N}`),
    //   * backward entries             (`...w{k}bwd`),
    //   * new SIMD-host entries        (`...@cpu{n}+{isa}w{k}`),
    // and strategies written before the microkernel axis existed (no
    // "isa" field) decode as the scalar lane they were measured on.
    let dir = std::env::temp_dir().join(format!("ukstc-tune-migrate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mixed.json");
    std::fs::write(
        &path,
        concat!(
            r#"{"version":1,"entries":{"#,
            // Legacy scalar host, pre-SIMD GEMM verdict: no "isa" field.
            r#""n4k4p2ci3co2@cpu8w2":"#,
            r#"{"seconds":1e-4,"strategy":{"axis":"phase-rows","formulation":"phase-gemm","workers":1}},"#,
            // Batched key on the same legacy host.
            r#""n4k4p2ci3co2@cpu8w2b4":"#,
            r#"{"seconds":2e-4,"strategy":{"axis":"phase-rows","formulation":"phase-gemm","workers":1,"fused":true}},"#,
            // Backward key on the same legacy host.
            r#""n4k4p2ci3co2@cpu8w2bwd":"#,
            r#"{"seconds":3e-4,"strategy":{"axis":"phase-rows","formulation":"phase","workers":1}},"#,
            // New-style SIMD host: `+avx2` fingerprint, explicit isa.
            r#""n4k4p2ci3co2@cpu8+avx2w2":"#,
            r#"{"seconds":4e-5,"strategy":{"axis":"phase-rows","formulation":"phase-gemm","workers":1,"isa":"avx2"}}"#,
            r#"}}"#
        ),
    )
    .unwrap();
    let mut cache = TuningCache::load(&path).unwrap();
    assert_eq!(cache.len(), 4, "all four key styles must load");

    // The decoded strategies mean what they measured: a pre-SIMD GEMM
    // verdict is the scalar microkernel, an explicit "isa" survives the
    // roundtrip, and unknown lanes are a load error (not silent data).
    use ukstc::conv::simd::Isa;
    use ukstc::util::json;
    let legacy = json::parse(
        r#"{"axis":"phase-rows","formulation":"phase-gemm","workers":1}"#,
    )
    .unwrap();
    assert_eq!(
        ExecStrategy::from_json(&legacy),
        Some(ExecStrategy::serial_gemm().with_isa(Isa::Scalar))
    );
    let tagged = json::parse(
        r#"{"axis":"phase-rows","formulation":"phase-gemm","workers":2,"isa":"avx2"}"#,
    )
    .unwrap();
    assert_eq!(
        ExecStrategy::from_json(&tagged),
        Some(ExecStrategy::gemm_parallel(2).with_isa(Isa::Avx2))
    );

    // A verdict recorded on *this* host coexists with all of the above
    // under the current fingerprint (ISA-suffixed on SIMD hosts).
    let p = ConvTransposeParams::new(4, 4, 2, 3, 2);
    cache.put(&p, 2, ExecStrategy::serial_gemm(), 5e-5);
    cache.save().unwrap();
    let reloaded = TuningCache::load(&path).unwrap();
    let hit = reloaded.get(&p, 2).expect("current-host entry must load back");
    assert_eq!(hit.strategy, ExecStrategy::serial_gemm());
    // 4 foreign entries + the current-host one — unless this host's
    // fingerprint happens to be the hand-authored `cpu8` one, in which
    // case the put overwrote the legacy entry.
    assert!(reloaded.len() >= 4);

    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_cache_is_an_error_not_a_crash() {
    let dir = std::env::temp_dir().join(format!("ukstc-tune-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "{not json").unwrap();
    assert!(TuningCache::load(&garbage).is_err());

    let wrong_version = dir.join("version.json");
    std::fs::write(&wrong_version, r#"{"version":99,"entries":{}}"#).unwrap();
    let err = TuningCache::load(&wrong_version).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    let bad_entry = dir.join("entry.json");
    std::fs::write(
        &bad_entry,
        r#"{"version":1,"entries":{"k":{"seconds":"fast"}}}"#,
    )
    .unwrap();
    assert!(TuningCache::load(&bad_entry).is_err());

    std::fs::remove_dir_all(&dir).ok();
}
