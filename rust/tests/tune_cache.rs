//! Tuning-cache contract (ISSUE 3 satellites): the cache roundtrips
//! through its JSON file, and a cache hit performs **zero**
//! measurements — proven by injecting a counting measurer, exactly the
//! seam `tune::measure::Measurer` exists for.

use ukstc::conv::plan::ConvTransposePlan;
use ukstc::conv::ConvTransposeParams;
use ukstc::tensor::Kernel;
use ukstc::tune::measure::Measurer;
use ukstc::tune::space::{ExecStrategy, ParAxis};
use ukstc::tune::{Tuner, TuningCache};
use ukstc::util::rng::Rng;

/// Deterministic counting measurer: every call is tallied; the
/// 2-worker phase-rows strategy is scripted to win.
struct CountingMeasurer {
    calls: usize,
}

impl Measurer for CountingMeasurer {
    fn time_strategy(
        &mut self,
        _plan: &ConvTransposePlan,
        strategy: &ExecStrategy,
        _incumbent: Option<f64>,
    ) -> Option<f64> {
        self.calls += 1;
        Some(if *strategy == ExecStrategy::parallel(2, ParAxis::PhaseRows) {
            1.0
        } else {
            2.0 + self.calls as f64 * 1e-3
        })
    }
}

fn plan_for(n_in: usize, cin: usize, cout: usize) -> ConvTransposePlan {
    let mut rng = Rng::seeded(0xCAFE);
    let k = Kernel::random(4, cin, cout, &mut rng);
    ConvTransposePlan::new(ConvTransposeParams::new(n_in, 4, 2, cin, cout), &k)
}

#[test]
fn cache_hit_skips_measurement() {
    let plan = plan_for(4, 3, 2);
    let tuner = Tuner::new(2);
    let mut cache = TuningCache::in_memory();
    let mut measurer = CountingMeasurer { calls: 0 };

    let first = tuner.tune_layer_cached(&plan, &mut cache, &mut measurer);
    assert!(!first.cached);
    assert_eq!(
        measurer.calls,
        tuner.space.len(),
        "a miss measures the whole space"
    );
    assert_eq!(first.strategy, ExecStrategy::parallel(2, ParAxis::PhaseRows));
    assert_eq!(first.best_seconds, 1.0);

    let calls_after_first = measurer.calls;
    let second = tuner.tune_layer_cached(&plan, &mut cache, &mut measurer);
    assert!(second.cached);
    assert_eq!(
        measurer.calls, calls_after_first,
        "a cache hit must perform zero measurements"
    );
    assert_eq!(second.strategy, first.strategy);
    assert_eq!(second.best_seconds, first.best_seconds);
    assert!(second.candidates.is_empty());

    // A different layer shape is a miss again.
    tuner.tune_layer_cached(&plan_for(8, 2, 3), &mut cache, &mut measurer);
    assert_eq!(measurer.calls, calls_after_first + tuner.space.len());
    assert_eq!(cache.len(), 2);
}

#[test]
fn cache_roundtrips_through_json_file() {
    let dir = std::env::temp_dir().join(format!("ukstc-tune-cache-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.json");
    let _ = std::fs::remove_file(&path);

    let tuner = Tuner::new(3);
    {
        // A missing file is an empty, path-backed cache.
        let mut cache = TuningCache::load(&path).unwrap();
        assert!(cache.is_empty());
        assert_eq!(cache.path(), Some(path.as_path()));
        let mut measurer = CountingMeasurer { calls: 0 };
        tuner.tune_layer_cached(&plan_for(4, 3, 2), &mut cache, &mut measurer);
        assert_eq!(measurer.calls, tuner.space.len());
        cache.save().unwrap();
    }

    // A fresh process-equivalent load must serve the verdict with zero
    // measurements — tuning pays once per machine.
    let mut reloaded = TuningCache::load(&path).unwrap();
    assert_eq!(reloaded.len(), 1);
    let mut measurer = CountingMeasurer { calls: 0 };
    let verdict = tuner.tune_layer_cached(&plan_for(4, 3, 2), &mut reloaded, &mut measurer);
    assert!(verdict.cached);
    assert_eq!(measurer.calls, 0, "persisted cache must skip measurement");
    assert_eq!(verdict.strategy, ExecStrategy::parallel(2, ParAxis::PhaseRows));
    assert_eq!(verdict.best_seconds, 1.0);

    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_cache_is_an_error_not_a_crash() {
    let dir = std::env::temp_dir().join(format!("ukstc-tune-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "{not json").unwrap();
    assert!(TuningCache::load(&garbage).is_err());

    let wrong_version = dir.join("version.json");
    std::fs::write(&wrong_version, r#"{"version":99,"entries":{}}"#).unwrap();
    let err = TuningCache::load(&wrong_version).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    let bad_entry = dir.join("entry.json");
    std::fs::write(
        &bad_entry,
        r#"{"version":1,"entries":{"k":{"seconds":"fast"}}}"#,
    )
    .unwrap();
    assert!(TuningCache::load(&bad_entry).is_err());

    std::fs::remove_dir_all(&dir).ok();
}
