//! Cross-module property suite: the algebraic invariants that tie the
//! paper's claims together, run wider than the per-module unit props.

use ukstc::conv::parallel::{run, Algorithm, Lane};
use ukstc::conv::plan::{ConvTransposePlan, Scratch};
use ukstc::conv::segregation::segregate;
use ukstc::conv::{flops, memory, out_size, unified, ConvTransposeParams};
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::tune::space::{search_space, ExecStrategy, Formulation};
use ukstc::util::prop::{close, forall, forall_res, Config};

/// Valid random geometry: guarantees a positive output size.
fn geometry(rng: &mut ukstc::util::rng::Rng) -> Option<(usize, usize, usize)> {
    let n_in = rng.range(1, 10);
    let nk = rng.range(2, 7);
    let p = rng.range(0, 4);
    (2 * n_in + 2 * p > nk).then_some((n_in, nk, p))
}

#[test]
fn prop_all_algorithms_agree_everywhere() {
    forall_res(
        Config::default().cases(80).seed(0xABCD),
        "all 5 algorithms × 2 lanes agree",
        |rng| {
            let Some((n_in, nk, p)) = geometry(rng) else {
                return ((0, 0, 0), Ok(()));
            };
            let cin = rng.range(1, 4);
            let cout = rng.range(1, 4);
            let mut r2 = rng.split();
            let x = Feature::random(n_in, n_in, cin, &mut r2);
            let k = Kernel::random(nk, cin, cout, &mut r2);
            let want = run(Algorithm::Conventional, Lane::Serial, &x, &k, p);
            for alg in Algorithm::all() {
                for lane in [Lane::Serial, Lane::Parallel(3)] {
                    let got = run(alg, lane, &x, &k, p);
                    if let Err(e) = close(&want.data, &got.data, 2e-3) {
                        return (
                            (n_in, nk, p),
                            Err(format!("{} {}: {e}", alg.name(), lane.name())),
                        );
                    }
                }
            }
            ((n_in, nk, p), Ok(()))
        },
    );
}

#[test]
fn prop_planned_bit_identical_to_one_shot() {
    // The plan/execute path must match the one-shot unified kernel
    // *bitwise* — same slabs, same correlation loops, same f32
    // accumulation order — on the full prop-test geometry grid, for
    // both the serial and the phase×row-parallel planned lanes.
    forall_res(
        Config::default().cases(60).seed(0x91A4),
        "plan.run == transpose_conv (bit-identical)",
        |rng| {
            let Some((n_in, nk, p)) = geometry(rng) else {
                return ((0, 0, 0, 0, 0), Ok(()));
            };
            let cin = rng.range(1, 4);
            let cout = rng.range(1, 4);
            let mut r2 = rng.split();
            let x = Feature::random(n_in, n_in, cin, &mut r2);
            let k = Kernel::random(nk, cin, cout, &mut r2);
            let want = unified::transpose_conv(&x, &k, p);
            let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::for_plan(&plan);
            let mut out = plan.new_output();
            plan.run(&x, &mut scratch, &mut out);
            let desc = (n_in, nk, p, cin, cout);
            if out != want {
                return (desc, Err("serial planned != one-shot bitwise".into()));
            }
            let mut out_par = plan.new_output();
            plan.run_par(&x, &mut scratch, &mut out_par, 3);
            if out_par != want {
                return (desc, Err("parallel planned != one-shot bitwise".into()));
            }
            (desc, Ok(()))
        },
    );
}

#[test]
fn prop_every_exec_strategy_matches_reference() {
    // The autotuner's whole search space (all three formulations,
    // every worker count × axis) against the planned serial reference
    // across the full random geometry grid (odd AND even output
    // sizes): the direct strategies must be bit-identical — the repo's
    // `==` convention — while the PhaseGemm strategies reassociate f32
    // sums through the tiled microkernel and must match within 1e-4
    // (ISSUE 4 acceptance; DESIGN.md §GEMM-Execution).  Every strategy
    // must also agree with the conventional Algorithm 1 oracle.
    let space = search_space(3);
    forall_res(
        Config::default().cases(40).seed(0x7E57),
        "ExecStrategy space equivalence",
        |rng| {
            let Some((n_in, nk, p)) = geometry(rng) else {
                return ((0, 0, 0, 0, 0), Ok(()));
            };
            let cin = rng.range(1, 4);
            let cout = rng.range(1, 4);
            let mut r2 = rng.split();
            let x = Feature::random(n_in, n_in, cin, &mut r2);
            let k = Kernel::random(nk, cin, cout, &mut r2);
            let conventional = run(Algorithm::Conventional, Lane::Serial, &x, &k, p);
            let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
            let mut scratch = Scratch::for_plan(&plan);
            let mut reference = plan.new_output();
            plan.run(&x, &mut scratch, &mut reference);
            let desc = (n_in, nk, p, cin, cout);
            for s in &space {
                let mut got = plan.new_output();
                got.data.fill(f32::NAN); // dirty buffer must be fully overwritten
                plan.run_with(s, &x, &mut scratch, &mut got);
                if s.formulation == Formulation::PhaseGemm {
                    if let Err(e) = close(&reference.data, &got.data, 1e-4) {
                        return (desc, Err(format!("{} vs reference: {e}", s.name())));
                    }
                } else if got != reference {
                    return (desc, Err(format!("{} != planned serial reference", s.name())));
                }
                if let Err(e) = close(&conventional.data, &got.data, 2e-3) {
                    return (desc, Err(format!("{} vs conventional: {e}", s.name())));
                }
            }
            (desc, Ok(()))
        },
    );
}

#[test]
fn phase_gemm_matches_reference_on_cout_grid() {
    // ISSUE 4 satellite: the PhaseGemm strategy ≈ planned serial
    // reference (1e-4) across odd AND even outputs, every padding
    // 0–3, and Cout values off the register-tile multiple
    // (NR = 8 → 1, 3, 17 are ragged, 8 is exact) — serial and
    // row-parallel lanes.
    let serial = ExecStrategy::serial_gemm();
    let par = ExecStrategy::gemm_parallel(3);
    for cout in [1usize, 3, 8, 17] {
        for p in 0..=3usize {
            for (n_in, nk) in [(4, 5), (4, 4), (5, 3), (3, 2), (6, 4)] {
                if 2 * n_in + 2 * p <= nk {
                    continue;
                }
                let mut rng = ukstc::util::rng::Rng::seeded(
                    0x6E44 ^ ((cout as u64) << 16) ^ ((p as u64) << 8) ^ (n_in as u64),
                );
                let x = Feature::random(n_in, n_in, 3, &mut rng);
                let k = Kernel::random(nk, 3, cout, &mut rng);
                let plan =
                    ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, 3, cout), &k);
                let mut scratch = Scratch::for_plan(&plan);
                let mut want = plan.new_output();
                plan.run(&x, &mut scratch, &mut want);
                for s in [&serial, &par] {
                    let mut got = plan.new_output();
                    got.data.fill(f32::NAN);
                    plan.run_with(s, &x, &mut scratch, &mut got);
                    close(&want.data, &got.data, 1e-4).unwrap_or_else(|e| {
                        panic!("{} (cout={cout} p={p} n={n_in} k={nk}): {e}", s.name())
                    });
                }
            }
        }
    }
}

#[test]
fn prop_scratch_arena_reuse_never_aliases() {
    // One arena threaded through a random sequence of differently-shaped
    // plans (shrinking and growing) must leave every result bit-identical
    // to a fresh computation — no stale slab/phase data leaks across runs.
    forall_res(
        Config::default().cases(25).seed(0x5C1A),
        "shared Scratch across shapes",
        |rng| {
            let mut shapes = Vec::new();
            for _ in 0..4 {
                if let Some((n_in, nk, p)) = geometry(rng) {
                    shapes.push((n_in, nk, p, rng.range(1, 3), rng.range(1, 3)));
                }
            }
            let mut r2 = rng.split();
            let cases: Vec<(Feature, ConvTransposePlan, Feature)> = shapes
                .iter()
                .map(|&(n_in, nk, p, cin, cout)| {
                    let x = Feature::random(n_in, n_in, cin, &mut r2);
                    let k = Kernel::random(nk, cin, cout, &mut r2);
                    let want = unified::transpose_conv(&x, &k, p);
                    let params = ConvTransposeParams::new(n_in, nk, p, cin, cout);
                    (x, ConvTransposePlan::new(params, &k), want)
                })
                .collect();
            let mut scratch = Scratch::new();
            for _round in 0..2 {
                for (x, plan, want) in cases.iter().chain(cases.iter().rev()) {
                    let mut out = plan.new_output();
                    plan.run(x, &mut scratch, &mut out);
                    if &out != want {
                        return (shapes.clone(), Err("stale scratch data aliased in".into()));
                    }
                }
            }
            (shapes, Ok(()))
        },
    );
}

#[test]
fn prop_linearity_in_input() {
    // Transpose conv is linear: T(a·x) = a·T(x).
    forall_res(Config::default().cases(40), "linearity", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), Ok(()));
        };
        let mut r2 = rng.split();
        let x = Feature::random(n_in, n_in, 2, &mut r2);
        let k = Kernel::random(nk, 2, 2, &mut r2);
        let mut x2 = x.clone();
        for v in &mut x2.data {
            *v *= 2.5;
        }
        let mut want = run(Algorithm::Unified, Lane::Serial, &x, &k, p);
        for v in &mut want.data {
            *v *= 2.5;
        }
        let got = run(Algorithm::Unified, Lane::Serial, &x2, &k, p);
        ((n_in, nk, p), close(&want.data, &got.data, 1e-2))
    });
}

#[test]
fn prop_additivity_in_kernel() {
    // T_{k1+k2}(x) = T_{k1}(x) + T_{k2}(x).
    forall_res(Config::default().cases(30), "kernel additivity", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), Ok(()));
        };
        let mut r2 = rng.split();
        let x = Feature::random(n_in, n_in, 2, &mut r2);
        let k1 = Kernel::random(nk, 2, 2, &mut r2);
        let k2 = Kernel::random(nk, 2, 2, &mut r2);
        let mut ks = k1.clone();
        for (a, b) in ks.data.iter_mut().zip(&k2.data) {
            *a += b;
        }
        let y1 = run(Algorithm::Unified, Lane::Serial, &x, &k1, p);
        let y2 = run(Algorithm::Unified, Lane::Serial, &x, &k2, p);
        let mut want = y1;
        for (a, b) in want.data.iter_mut().zip(&y2.data) {
            *a += b;
        }
        let got = run(Algorithm::Unified, Lane::Serial, &x, &ks, p);
        ((n_in, nk, p), close(&want.data, &got.data, 1e-2))
    });
}

#[test]
fn prop_zero_input_zero_output() {
    forall(Config::default().cases(20), "zero in, zero out", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), true);
        };
        let mut r2 = rng.split();
        let x = Feature::zeros(n_in, n_in, 2);
        let k = Kernel::random(nk, 2, 2, &mut r2);
        let y = run(Algorithm::Unified, Lane::Serial, &x, &k, p);
        ((n_in, nk, p), y.data.iter().all(|&v| v == 0.0))
    });
}

#[test]
fn prop_flop_model_bounds_hold() {
    forall(Config::default().cases(60), "flop bounds", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), true);
        };
        let params = ConvTransposeParams::new(n_in, nk, p, 2, 3);
        let conv = flops::conventional(&params);
        let uni = flops::unified(&params);
        let grp = flops::grouped(&params);
        let ok = uni <= grp && grp <= conv && uni > 0
            && (params.odd_output() || grp == uni);
        ((n_in, nk, p), ok)
    });
}

#[test]
fn prop_memory_model_invariants() {
    forall(Config::default().cases(60), "memory invariants", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), true);
        };
        let params = ConvTransposeParams::new(n_in, nk, p, 3, 2);
        let t4 = memory::savings_table4(&params);
        let t2 = memory::savings_table2(&params);
        let conv_fp = memory::footprint_conventional(&params).total();
        let uni_fp = memory::footprint_unified(&params).total();
        let ok = t2 <= t4 && conv_fp > uni_fp && conv_fp - uni_fp == t2;
        ((n_in, nk, p), ok)
    });
}

#[test]
fn prop_segregation_taps_conserved() {
    forall(Config::default().cases(40), "segregation conserves taps", |rng| {
        let nk = rng.range(2, 9);
        let mut r2 = rng.split();
        let k = Kernel::random(nk, 2, 2, &mut r2);
        let seg = segregate(&k);
        let sum: f32 = k.data.iter().sum();
        let seg_sum: f32 = seg.subs.iter().map(|s| s.data.iter().sum::<f32>()).sum();
        (nk, (sum - seg_sum).abs() < 1e-3 * sum.abs().max(1.0))
    });
}

#[test]
fn prop_output_size_consistency() {
    forall(Config::default().cases(50), "output size", |rng| {
        let Some((n_in, nk, p)) = geometry(rng) else {
            return ((0, 0, 0), true);
        };
        let mut r2 = rng.split();
        let x = Feature::random(n_in, n_in, 1, &mut r2);
        let k = Kernel::random(nk, 1, 1, &mut r2);
        let y = run(Algorithm::Unified, Lane::Serial, &x, &k, p);
        let expect = out_size(n_in, nk, p);
        ((n_in, nk, p), y.h == expect && y.w == expect)
    });
}

#[test]
fn prop_upsample_crop_adjoint() {
    // Sanity on the tensor substrate: upsample places exactly the
    // original pixels at even coordinates.
    forall(Config::default().cases(30), "upsample adjoint", |rng| {
        let n = rng.range(1, 12);
        let c = rng.range(1, 4);
        let mut r2 = rng.split();
        let x = Feature::random(n, n, c, &mut r2);
        let up = ops::upsample_bed_of_nails(&x);
        let back = ops::extract_phase(&up, 0, 0);
        (n, back == x)
    });
}
