//! Counting-allocator proof of the plan/execute contract
//! (DESIGN.md §Plan-Execute):
//!
//! 1. steady-state `ConvTransposePlan::run` performs **zero** heap
//!    allocations once the scratch arena is at its high-water mark,
//! 2. the planned phase-GEMM engine (`run_gemm`, DESIGN.md
//!    §GEMM-Execution) is equally allocation-free in steady state —
//!    its im2col patch matrix lives in the arena and its packed
//!    kernel operands live in the plan, and
//! 3. the unplanned unified path's `phase_slab` crops straight into a
//!    single fresh slab — the old full-input clone and pad+crop double
//!    copy stay gone.
//!
//! This file deliberately holds exactly one `#[test]`: the global
//! allocation counter is process-wide, and a sibling test running on
//! another harness thread would perturb it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use ukstc::conv::plan::{ConvTransposePlan, Scratch};
use ukstc::conv::segregation::segregate;
use ukstc::conv::unified;
use ukstc::conv::ConvTransposeParams;
use ukstc::tensor::{ops, Feature, FeatureBatch, Kernel};
use ukstc::tune::space::ExecStrategy;
use ukstc::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn planned_path_is_zero_alloc_after_warmup() {
    // --- Part 1: zero allocations in steady state, across a stack of
    // differently-shaped layers sharing one arena (the generator
    // shape: GAN blocks k=4, P=2, shrunk channels).
    let mut rng = Rng::seeded(0xA110C);
    let shapes = [(4usize, 16usize, 8usize), (8, 8, 4), (5, 3, 2)];
    let cases: Vec<(Feature, ConvTransposePlan, Feature)> = shapes
        .iter()
        .map(|&(n, cin, cout)| {
            let x = Feature::random(n, n, cin, &mut rng);
            let k = Kernel::random(4, cin, cout, &mut rng);
            let params = ConvTransposeParams::new(n, 4, 2, cin, cout);
            let plan = ConvTransposePlan::new(params, &k);
            let out = plan.new_output();
            (x, plan, out)
        })
        .collect();
    let mut outs: Vec<Feature> = cases.iter().map(|(_, _, out)| out.clone()).collect();
    let mut scratch = Scratch::new();
    // Warm-up: the arena grows to the high-water mark of the stack.
    for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
        plan.run(x, &mut scratch, out);
    }
    let before = allocs();
    for _ in 0..5 {
        for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
            plan.run(x, &mut scratch, out);
        }
    }
    assert_eq!(
        allocs(),
        before,
        "plan.run heap-allocated in steady state (warm arena)"
    );
    // A pre-sized arena is warm from call one.
    let mut exact = Scratch::for_plans(cases.iter().map(|(_, plan, _)| plan));
    let before = allocs();
    for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
        plan.run(x, &mut scratch, out);
        plan.run(x, &mut exact, out);
    }
    assert_eq!(allocs(), before, "pre-sized arena still allocated");

    // Results stay correct after all that reuse.
    for ((x, plan, _), out) in cases.iter().zip(&outs) {
        let want = unified::transpose_conv_seg(x, plan.seg(), 2);
        assert_eq!(out, &want, "planned result diverged after arena reuse");
    }

    // --- Part 2: the phase-GEMM engine is zero-alloc in steady state
    // too (ISSUE 4 acceptance).  One warm-up pass grows the shared
    // arena to the GEMM high-water mark (its im2col patch region);
    // after that, im2col + packed GEMM + scatter touch only the arena
    // and the plan's packed operands.
    let gemm = ExecStrategy::serial_gemm();
    for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
        plan.run_with(&gemm, x, &mut scratch, out);
    }
    let before = allocs();
    for _ in 0..5 {
        for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
            plan.run_with(&gemm, x, &mut scratch, out);
        }
    }
    assert_eq!(
        allocs(),
        before,
        "run_gemm heap-allocated in steady state (warm arena)"
    );
    for ((x, plan, _), out) in cases.iter().zip(&outs) {
        let want = unified::transpose_conv_seg(x, plan.seg(), 2);
        assert!(
            ops::max_abs_diff(out, &want) < 1e-4,
            "phase-GEMM result diverged after arena reuse"
        );
    }

    // --- Part 3: the unplanned path's slab construction is single-copy.
    // With this geometry no phase needs padding, so each phase costs
    // exactly one slab + one phase buffer; plus the output and the
    // geometry Vec that is 2 + 2·phases allocations total.  The old
    // clone-then-crop path cost 3 per phase.
    let x = Feature::random(4, 4, 3, &mut rng);
    let k = Kernel::random(4, 3, 2, &mut rng);
    let seg = segregate(&k);
    let geoms = unified::phase_geometries(4, 4, 0);
    assert!(geoms.iter().all(|g| g.pads == (0, 0, 0, 0)));
    let before = allocs();
    let out = unified::transpose_conv_seg(&x, &seg, 0);
    let spent = allocs() - before;
    assert!(
        spent <= 2 + 2 * geoms.len(),
        "phase_slab full-copy path is back: {spent} allocations for {} phases",
        geoms.len()
    );
    assert_eq!((out.h, out.w, out.c), (4, 4, 2));

    // --- Part 4: the batched lanes (ISSUE 5) extend the zero-alloc
    // guarantee: serial batched direct and the fused batched GEMM touch
    // only the warm arena, the plan's packed operands, and the
    // caller-owned FeatureBatch buffers.  One warm-up pass grows the
    // shared arena to the batched high-water mark; after that, nothing.
    let (_, plan0, _) = &cases[0];
    let batch = 3;
    let xb = FeatureBatch::random(batch, 4, 4, 16, &mut rng);
    let mut outb = plan0.new_batch_output(batch);
    plan0.run_batch(&xb, &mut scratch, &mut outb);
    plan0.run_gemm_batch(&xb, &mut scratch, &mut outb);
    let before = allocs();
    for _ in 0..5 {
        plan0.run_batch(&xb, &mut scratch, &mut outb);
        plan0.run_gemm_batch(&xb, &mut scratch, &mut outb);
    }
    assert_eq!(
        allocs(),
        before,
        "batched lanes heap-allocated in steady state (warm arena)"
    );
    // Results stay correct after all that reuse (GEMM ran last, so the
    // 1e-4 reassociation tolerance applies).
    for i in 0..batch {
        let want = unified::transpose_conv_seg(&xb.feature(i), plan0.seg(), 2);
        let got = Feature::from_vec(want.h, want.w, want.c, outb.image(i).to_vec());
        assert!(
            ops::max_abs_diff(&got, &want) < 1e-4,
            "batched result diverged after arena reuse (image {i})"
        );
    }

    // --- Part 5: the backward lanes (DESIGN.md §Backward-Execution)
    // honor the same contract.  A dedicated plan (same geometry as
    // `plan0`, kernel kept for the one-shot reference) proves first
    // that the sizing is *exact*: each lane's `scratch_floats_backward*`
    // figure is precisely what a cold arena grows to — no more, no less.
    let k5 = Kernel::random(4, 16, 8, &mut rng);
    let plan5 = ConvTransposePlan::new(ConvTransposeParams::new(4, 4, 2, 16, 8), &k5);
    let out5 = plan5.params().out_size();
    let dy0 = Feature::random(out5, out5, 8, &mut rng);
    let x0 = Feature::random(4, 4, 16, &mut rng);
    let mut dx0 = plan5.new_input_grad();
    let mut dk0 = plan5.new_kernel_grad();
    {
        let mut cold = Scratch::new();
        plan5.run_backward_data(&dy0, &mut cold, &mut dx0);
        assert_eq!(
            cold.capacity_floats(),
            plan5.scratch_floats_backward_data(),
            "backward-data direct sizing is not exact"
        );
        let mut cold = Scratch::new();
        plan5.run_backward_data_gemm(&dy0, &mut cold, &mut dx0);
        assert_eq!(
            cold.capacity_floats(),
            plan5.scratch_floats_backward_data_gemm(),
            "backward-data GEMM sizing is not exact"
        );
        let mut cold = Scratch::new();
        plan5.run_backward_weights(&x0, &dy0, &mut cold, &mut dk0);
        assert_eq!(
            cold.capacity_floats(),
            plan5.scratch_floats_backward_weights(),
            "backward-weights sizing is not exact"
        );
        // The fused backward (one dy-phase extraction shared between
        // data-grad and weight-grad) has its own exact figure: the
        // shared dense-phase regions plus the *larger* of the forward
        // and backward im2col patches, plus the packed-dy and dsub
        // regions.
        let mut cold = Scratch::new();
        plan5.run_backward(&x0, &dy0, &mut cold, &mut dx0, &mut dk0);
        assert_eq!(
            cold.capacity_floats(),
            plan5.scratch_floats_backward_fused(),
            "fused backward sizing is not exact"
        );
        assert_eq!(
            plan5.peak_scratch_floats_backward(),
            plan5
                .scratch_floats_backward_data_gemm()
                .max(plan5.scratch_floats_backward_weights())
                .max(plan5.scratch_floats_backward_fused()),
            "backward peak must be the max over the lanes"
        );
    }
    // Then steady state: with the shared arena at the backward
    // high-water mark, every backward lane — single-image direct and
    // GEMM data-grad, the batched data-grad, single and batched
    // weight-grad — performs zero heap allocations.
    let mut dxb = FeatureBatch::zeros(batch, 4, 4, 16);
    let dyb = FeatureBatch::random(batch, out5, out5, 8, &mut rng);
    // One warm-up round grows the shared arena to the backward
    // high-water mark.
    plan5.run_backward_data(&dy0, &mut scratch, &mut dx0);
    plan5.run_backward_data_gemm(&dy0, &mut scratch, &mut dx0);
    plan5.run_backward_data_batch(&dyb, &mut scratch, &mut dxb);
    plan5.run_backward_weights(&x0, &dy0, &mut scratch, &mut dk0);
    plan5.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut dk0);
    plan5.run_backward(&x0, &dy0, &mut scratch, &mut dx0, &mut dk0);
    plan5.run_backward_batch(&xb, &dyb, &mut scratch, &mut dxb, &mut dk0);
    let before = allocs();
    for _ in 0..5 {
        plan5.run_backward_data(&dy0, &mut scratch, &mut dx0);
        plan5.run_backward_data_gemm(&dy0, &mut scratch, &mut dx0);
        plan5.run_backward_data_batch(&dyb, &mut scratch, &mut dxb);
        plan5.run_backward_weights(&x0, &dy0, &mut scratch, &mut dk0);
        plan5.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut dk0);
        plan5.run_backward(&x0, &dy0, &mut scratch, &mut dx0, &mut dk0);
        plan5.run_backward_batch(&xb, &dyb, &mut scratch, &mut dxb, &mut dk0);
    }
    assert_eq!(
        allocs(),
        before,
        "backward lanes heap-allocated in steady state (warm arena)"
    );
    // And the reused-buffer results still match the one-shot reference.
    use ukstc::conv::backward::{grad_input_unified, grad_kernel_unified};
    let want_dx = grad_input_unified(&dy0, &k5, 4, 2);
    plan5.run_backward_data(&dy0, &mut scratch, &mut dx0);
    assert_eq!(dx0, want_dx, "backward data diverged after arena reuse");
    plan5.run_backward_weights(&x0, &dy0, &mut scratch, &mut dk0);
    let want_dk = grad_kernel_unified(&x0, &dy0, 4, 2);
    let dk_err = dk0
        .data
        .iter()
        .zip(&want_dk.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(dk_err < 1e-4, "backward weights diverged after arena reuse");
    // The fused lane too: dx bit-identical to the unfused direct lane,
    // dk within the GEMM reassociation tolerance.
    plan5.run_backward(&x0, &dy0, &mut scratch, &mut dx0, &mut dk0);
    assert_eq!(dx0, want_dx, "fused backward dx diverged after arena reuse");
    let dk_err = dk0
        .data
        .iter()
        .zip(&want_dk.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(dk_err < 1e-4, "fused backward dk diverged after arena reuse");

    // --- Part 6: the span recorder (ISSUE 8) preserves the contract.
    // Every run path above now opens trace spans; parts 1–5 therefore
    // already prove the *disabled* recorder adds no allocations.  Make
    // that explicit, then prove the *enabled* recorder costs exactly
    // one bounded per-thread setup and is allocation-free in steady
    // state (the ring is preallocated and overwrites in place).
    use ukstc::obs::trace;
    assert!(!trace::enabled(), "tracing must start disabled in this binary");
    let before = allocs();
    for _ in 0..5 {
        for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
            plan.run_with(&gemm, x, &mut scratch, out);
        }
    }
    assert_eq!(
        allocs(),
        before,
        "disabled tracing allocated on the instrumented run path"
    );
    // Enabled: the first recorded span on this thread builds its ring
    // (Arc + preallocated slot Vec + drain-list registration) — a
    // small one-time setup, nothing more.
    trace::enable_with_capacity(64);
    let before = allocs();
    {
        let (x, plan, _) = &cases[0];
        plan.run_with(&gemm, x, &mut scratch, &mut outs[0]);
    }
    let setup = allocs() - before;
    assert!(
        setup <= 16,
        "tracing-enabled first run should cost only the ring setup, got {setup} allocations"
    );
    // Steady state with tracing on: the ring fills, then overwrites
    // oldest in place — zero heap allocations either way.
    let before = allocs();
    for _ in 0..5 {
        for ((x, plan, _), out) in cases.iter().zip(&mut outs) {
            plan.run_with(&gemm, x, &mut scratch, out);
        }
    }
    assert_eq!(
        allocs(),
        before,
        "enabled tracing allocated in steady state (warm ring)"
    );
    trace::disable();
    let spans = trace::drain();
    assert!(
        spans.iter().any(|r| r.name == "conv.forward"),
        "traced runs should have recorded conv.forward spans"
    );
    assert!(
        spans.iter().any(|r| r.name == "conv.phase"),
        "traced runs should have recorded per-phase spans"
    );

    // --- Part 7: the quantized phase-GEMM lanes (ISSUE 9, DESIGN.md
    // §Reduced-Precision) extend the contract.  Exact sizing first: a
    // cold arena grows its f32 region to `scratch_floats` and its
    // reduced-precision lane to exactly `quant_patch_elems` elements —
    // and the u16 (f16/bf16) and i8 arenas grow independently, each
    // only when its own lane first runs.
    use ukstc::conv::quant::Precision;
    let f16 = ExecStrategy::serial_gemm().with_precision(Precision::F16);
    let bf16 = ExecStrategy::serial_gemm().with_precision(Precision::Bf16);
    let int8 = ExecStrategy::serial_gemm().with_precision(Precision::Int8);
    let x0c = &cases[0].0;
    let mut out7 = plan0.new_output();
    let mut outb7 = plan0.new_batch_output(batch);
    {
        let mut cold = Scratch::new();
        plan0.run_with(&f16, x0c, &mut cold, &mut out7);
        assert_eq!(
            cold.capacity_floats(),
            plan0.scratch_floats(),
            "quantized serial f32-region sizing is not exact"
        );
        assert_eq!(
            cold.q16_capacity_elems(),
            plan0.quant_patch_elems(),
            "16-bit quantized-patch sizing is not exact"
        );
        assert_eq!(
            cold.q8_capacity_elems(),
            0,
            "the 16-bit lane must not grow the int8 arena"
        );
        plan0.run_with(&int8, x0c, &mut cold, &mut out7);
        assert_eq!(
            cold.q8_capacity_elems(),
            plan0.quant_patch_elems(),
            "int8 quantized-patch sizing is not exact"
        );
        assert_eq!(
            cold.q16_capacity_elems(),
            plan0.quant_patch_elems(),
            "the int8 lane must not grow the 16-bit arena"
        );
        // Fused batched quantized sizing: the stacked [N·rows, K]
        // patch quantizes whole — exactly N× the per-image elements.
        plan0.run_batch_with(&f16, &xb, &mut cold, &mut outb7);
        assert_eq!(
            cold.q16_capacity_elems(),
            plan0.quant_patch_elems_batch(batch),
            "batched 16-bit quantized-patch sizing is not exact"
        );
        assert_eq!(
            cold.capacity_floats(),
            plan0.scratch_floats_gemm_batch(batch).max(plan0.scratch_floats()),
            "batched quantized f32-region sizing is not exact"
        );
    }
    // Steady state: warm the shared arena across every serial
    // quantized lane (single-image and fused batched, all three
    // precisions), then nothing allocates — the quantized patch lives
    // in the arena's reduced-precision lanes and the quantized packed
    // panels (and int8 scales) live in the plan.
    for s in [&f16, &bf16, &int8] {
        plan0.run_with(s, x0c, &mut scratch, &mut out7);
        plan0.run_batch_with(s, &xb, &mut scratch, &mut outb7);
    }
    let before = allocs();
    for _ in 0..5 {
        for s in [&f16, &bf16, &int8] {
            plan0.run_with(s, x0c, &mut scratch, &mut out7);
            plan0.run_batch_with(s, &xb, &mut scratch, &mut outb7);
        }
    }
    assert_eq!(
        allocs(),
        before,
        "quantized lanes heap-allocated in steady state (warm arena)"
    );
    // Results stay within the documented drift bound after all that
    // reuse (int8 ran last — the loosest lane; bound per DESIGN.md
    // §Reduced-Precision: ≤ cin·⌈k/2⌉² products per output element,
    // each operand within absmax/254 of its f32 value, 2× margin).
    let want = unified::transpose_conv_seg(x0c, plan0.seg(), 2);
    let amax = x0c.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let kmax = plan0
        .seg()
        .subs
        .iter()
        .flat_map(|s| &s.data)
        .fold(0.0f32, |m, v| m.max(v.abs()));
    let bound = (16 * 2 * 2) as f32 * amax * kmax / 64.0;
    assert!(
        ops::max_abs_diff(&out7, &want) <= bound,
        "int8 lane diverged past its drift bound after arena reuse"
    );
    for i in 0..batch {
        let want = unified::transpose_conv_seg(&xb.feature(i), plan0.seg(), 2);
        let got = Feature::from_vec(want.h, want.w, want.c, outb7.image(i).to_vec());
        assert!(
            ops::max_abs_diff(&got, &want) <= bound,
            "batched int8 lane diverged past its drift bound (image {i})"
        );
    }

    // --- Part 8: the fused-epilogue lanes (ISSUE 10, DESIGN.md
    // §Fused-Epilogue) tighten the contract: GEMM tiles store straight
    // into the strided output, so the arena drops the phase-slab
    // region entirely.  Exact sizing first — and *strictly smaller*
    // than the separate route's figure.
    use ukstc::conv::gemm::{Activation, Epilogue};
    let fused = ExecStrategy::serial_gemm().fused_epilogue();
    let bias8 = Feature::random(1, 1, 8, &mut rng).data;
    let epi8 = Epilogue {
        bias: Some(&bias8[..]),
        act: Activation::Relu,
    };
    let mut out8 = plan0.new_output();
    let mut outb8 = plan0.new_batch_output(batch);
    {
        let mut cold = Scratch::new();
        plan0.run_with_epilogue(&fused, x0c, &mut cold, &mut out8, &epi8);
        assert_eq!(
            cold.capacity_floats(),
            plan0.scratch_floats_gemm_fused(),
            "fused-epilogue sizing is not exact"
        );
        assert!(
            plan0.scratch_floats_gemm_fused() < plan0.scratch_floats(),
            "fused epilogue must need strictly less scratch than slab+scatter"
        );
        let fused_b = ExecStrategy::serial_gemm().fused().fused_epilogue();
        let mut cold = Scratch::new();
        plan0.run_batch_with_epilogue(&fused_b, &xb, &mut cold, &mut outb8, &epi8);
        assert_eq!(
            cold.capacity_floats(),
            plan0.scratch_floats_gemm_batch_fused(batch),
            "batched fused-epilogue sizing is not exact"
        );
        assert!(
            plan0.scratch_floats_gemm_batch_fused(batch) < plan0.scratch_floats_gemm_batch(batch),
            "batched fused epilogue must need strictly less scratch"
        );
    }
    // Steady state: the fused single-image, batched, and quantized
    // lanes touch only the warm arena, the plan's packed operands, and
    // the caller's output — zero heap allocations.
    let fused_b = ExecStrategy::serial_gemm().fused().fused_epilogue();
    let f16_fused = ExecStrategy::serial_gemm()
        .with_precision(Precision::F16)
        .fused_epilogue();
    plan0.run_with_epilogue(&fused, x0c, &mut scratch, &mut out8, &epi8);
    plan0.run_batch_with_epilogue(&fused_b, &xb, &mut scratch, &mut outb8, &epi8);
    plan0.run_with_epilogue(&f16_fused, x0c, &mut scratch, &mut out8, &epi8);
    let before = allocs();
    for _ in 0..5 {
        plan0.run_with_epilogue(&fused, x0c, &mut scratch, &mut out8, &epi8);
        plan0.run_batch_with_epilogue(&fused_b, &xb, &mut scratch, &mut outb8, &epi8);
        plan0.run_with_epilogue(&f16_fused, x0c, &mut scratch, &mut out8, &epi8);
    }
    assert_eq!(
        allocs(),
        before,
        "fused-epilogue lanes heap-allocated in steady state (warm arena)"
    );
    // Results stay correct after all that reuse: the f32 fused lane
    // ran via run_with_epilogue, so compare against the separate
    // reference with the same bias+ReLU applied (GEMM reassociation
    // tolerance).
    plan0.run_with_epilogue(&fused, x0c, &mut scratch, &mut out8, &epi8);
    let mut want8 = unified::transpose_conv_seg(x0c, plan0.seg(), 2);
    for px in want8.data.chunks_exact_mut(8) {
        for (v, b) in px.iter_mut().zip(&bias8) {
            *v = (*v + b).max(0.0);
        }
    }
    assert!(
        ops::max_abs_diff(&out8, &want8) < 1e-4,
        "fused-epilogue result diverged after arena reuse"
    );
}
