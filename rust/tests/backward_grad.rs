//! End-to-end gradient checks for every backward route (DESIGN.md
//! §Backward-Execution): central finite differences against the
//! conventional one-shot gradients, the unified one-shot gradients,
//! and the planned lanes (direct / phase-GEMM / phase-row-parallel
//! data-grad, phase-GEMM weight-grad) over a grid of odd/even shapes,
//! paddings 0–3 and `Cout ∈ {1, 3, 8}` — plus the batched contract:
//! the planned batched backward is bit-identical to `N` sequential
//! unplanned backwards on direct lanes and within 1e-4 on GEMM lanes
//! (the PR-4 reassociation tolerance).
//!
//! The probe loss is `L = Σ w ⊙ y` for a fixed random `w`, so `L` is
//! *linear* in both `x` and `k`: central differences carry no
//! truncation term and a large step (0.5) keeps the f32 rounding noise
//! far below the 1e-3 relative tolerance.

use ukstc::conv::backward::{
    grad_input_conventional, grad_input_unified, grad_kernel_conventional, grad_kernel_unified,
};
use ukstc::conv::plan::{ConvTransposePlan, Scratch};
use ukstc::conv::{unified, ConvTransposeParams};
use ukstc::tensor::{Feature, FeatureBatch, Kernel};
use ukstc::tune::{backward_search_space, Formulation};
use ukstc::util::rng::Rng;

/// `L = Σ w ⊙ y`, accumulated in f64 so the FD quotient's rounding
/// noise stays well under the comparison tolerance.
fn probe_loss(y: &Feature, w: &Feature) -> f64 {
    y.data
        .iter()
        .zip(&w.data)
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum()
}

fn check(got: f32, fd: f64, what: &str) {
    let fd = fd as f32;
    assert!(
        (got - fd).abs() <= 1e-3 * (1.0 + fd.abs()),
        "{what}: analytic {got} vs central FD {fd}"
    );
}

/// The shape grid: odd and even inputs and kernels, paddings 0–3,
/// `Cout ∈ {1, 3, 8}`, skipping configurations whose padded upsampled
/// map cannot host the kernel (`2·n_in + 2·p ≤ n_k`) or whose output
/// would be empty.
fn grid() -> Vec<(usize, usize, usize, usize, usize)> {
    let mut cases = Vec::new();
    for n_in in [3usize, 4, 5] {
        for nk in [3usize, 4] {
            for p in 0usize..=3 {
                for cout in [1usize, 3, 8] {
                    // out_size = 2·n_in + 2·p − n_k must be positive.
                    if 2 * n_in + 2 * p <= nk {
                        continue;
                    }
                    cases.push((n_in, nk, p, 2usize, cout));
                }
            }
        }
    }
    cases
}

#[test]
fn data_grad_routes_match_finite_differences() {
    for (ci, &(n_in, nk, p, cin, cout)) in grid().iter().enumerate() {
        let mut rng = Rng::seeded(0xBAD0 ^ (ci as u64));
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let y0 = unified::transpose_conv(&x, &k, p);
        let w = Feature::random(y0.h, y0.w, y0.c, &mut rng);
        // dL/dy = w for the linear probe loss.
        let conv = grad_input_conventional(&w, &k, n_in, p);
        let uni = grad_input_unified(&w, &k, n_in, p);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
        let mut direct = plan.new_input_grad();
        plan.run_backward_data(&w, &mut scratch, &mut direct);
        let mut gemm = plan.new_input_grad();
        plan.run_backward_data_gemm(&w, &mut scratch, &mut gemm);
        let mut par = plan.new_input_grad();
        plan.run_backward_data_par(&w, &mut scratch, &mut par, 3);
        // The planned direct lanes reproduce the one-shot unified
        // reference bit-for-bit; GEMM stays within 1e-4.
        assert_eq!(direct, uni, "case {ci}: planned direct != one-shot");
        assert_eq!(par, uni, "case {ci}: planned parallel != one-shot");
        for (a, b) in gemm.data.iter().zip(&uni.data) {
            assert!((a - b).abs() < 1e-4, "case {ci}: GEMM lane drifted");
        }
        let eps = 0.5f32;
        let step = x.data.len() / 6 + 1;
        for idx in (0..x.data.len()).step_by(step) {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (probe_loss(&unified::transpose_conv(&xp, &k, p), &w)
                - probe_loss(&unified::transpose_conv(&xm, &k, p), &w))
                / (2.0 * eps as f64);
            let what = format!("case {ci} (n{n_in} k{nk} p{p} co{cout}) dx[{idx}]");
            check(conv.data[idx], fd, &format!("{what} conventional"));
            check(uni.data[idx], fd, &format!("{what} unified"));
            check(direct.data[idx], fd, &format!("{what} planned-direct"));
            check(gemm.data[idx], fd, &format!("{what} planned-gemm"));
            check(par.data[idx], fd, &format!("{what} planned-par"));
        }
    }
}

#[test]
fn weight_grad_routes_match_finite_differences() {
    for (ci, &(n_in, nk, p, cin, cout)) in grid().iter().enumerate() {
        let mut rng = Rng::seeded(0xBAD1 ^ (ci as u64));
        let x = Feature::random(n_in, n_in, cin, &mut rng);
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let y0 = unified::transpose_conv(&x, &k, p);
        let w = Feature::random(y0.h, y0.w, y0.c, &mut rng);
        let conv = grad_kernel_conventional(&x, &w, nk, p);
        let uni = grad_kernel_unified(&x, &w, nk, p);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
        let mut planned = plan.new_kernel_grad();
        plan.run_backward_weights(&x, &w, &mut scratch, &mut planned);
        let eps = 0.5f32;
        let step = k.data.len() / 6 + 1;
        for idx in (0..k.data.len()).step_by(step) {
            let mut kp = k.clone();
            kp.data[idx] += eps;
            let mut km = k.clone();
            km.data[idx] -= eps;
            let fd = (probe_loss(&unified::transpose_conv(&x, &kp, p), &w)
                - probe_loss(&unified::transpose_conv(&x, &km, p), &w))
                / (2.0 * eps as f64);
            let what = format!("case {ci} (n{n_in} k{nk} p{p} co{cout}) dk[{idx}]");
            check(conv.data[idx], fd, &format!("{what} conventional"));
            check(uni.data[idx], fd, &format!("{what} unified"));
            check(planned.data[idx], fd, &format!("{what} planned"));
        }
    }
}

#[test]
fn planned_batched_backward_matches_sequential_unplanned() {
    // The batched contract against the *unplanned* one-shot reference:
    // direct lanes bit-identical to N sequential `grad_input_unified`
    // calls, GEMM lanes within 1e-4; the batch-accumulated weight-grad
    // within 1e-3 of the per-image sum (one extra reassociation per
    // image).
    let shapes = [
        (4usize, 4usize, 2usize, 3usize, 8usize),
        (5, 3, 1, 2, 3),
        (3, 4, 3, 2, 1),
        (6, 4, 2, 2, 8),
    ];
    for (si, &(n_in, nk, p, cin, cout)) in shapes.iter().enumerate() {
        let mut rng = Rng::seeded(0xBAD2 ^ (si as u64));
        let k = Kernel::random(nk, cin, cout, &mut rng);
        let plan = ConvTransposePlan::new(ConvTransposeParams::new(n_in, nk, p, cin, cout), &k);
        let out = plan.params().out_size();
        for n in [1usize, 3, 5] {
            let xb = FeatureBatch::random(n, n_in, n_in, cin, &mut rng);
            let dyb = FeatureBatch::random(n, out, out, cout, &mut rng);
            // Sequential unplanned reference.
            let mut want_dx = Vec::with_capacity(n);
            let mut want_dk = plan.new_kernel_grad();
            for i in 0..n {
                let xi = xb.feature(i);
                let dyi = dyb.feature(i);
                want_dx.push(grad_input_unified(&dyi, &k, n_in, p));
                let dki = grad_kernel_unified(&xi, &dyi, nk, p);
                for (a, b) in want_dk.data.iter_mut().zip(&dki.data) {
                    *a += b;
                }
            }
            let mut scratch = Scratch::with_floats(plan.peak_scratch_floats_backward());
            for s in backward_search_space(4) {
                let mut dxb = FeatureBatch::zeros(n, n_in, n_in, cin);
                plan.run_backward_data_batch_with(&s, &dyb, &mut scratch, &mut dxb);
                for (i, want) in want_dx.iter().enumerate() {
                    if s.formulation == Formulation::PhaseGemm {
                        let err = dxb
                            .image(i)
                            .iter()
                            .zip(&want.data)
                            .map(|(a, b)| (a - b).abs())
                            .fold(0.0f32, f32::max);
                        assert!(err < 1e-4, "{} image {i} err {err}", s.name());
                    } else {
                        assert_eq!(
                            dxb.image(i),
                            &want.data[..],
                            "{} image {i} not bit-identical (shape {si}, n {n})",
                            s.name()
                        );
                    }
                }
            }
            let mut dk = plan.new_kernel_grad();
            plan.run_backward_weights_batch(&xb, &dyb, &mut scratch, &mut dk);
            let err = dk
                .data
                .iter()
                .zip(&want_dk.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 1e-3, "batched weight-grad err {err} (shape {si}, n {n})");
        }
    }
}
