//! `cargo bench --bench serving` — end-to-end serving A/B: identical
//! coordinator (router + dynamic batcher + worker pool), backend kernel
//! switched between unified (proposed) and conventional (baseline).

use ukstc::bench::serving::{print_ab, run_ab, ServingConfig};
use ukstc::models::GanModel;

fn main() {
    let requests = std::env::var("UKSTC_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let model = std::env::var("UKSTC_BENCH_MODEL")
        .ok()
        .and_then(|v| GanModel::from_name(&v))
        .unwrap_or(GanModel::GpGan);
    let cfg = ServingConfig {
        model,
        requests,
        ..Default::default()
    };
    eprintln!(
        "serving A/B: model={} requests={} workers={} max_batch={}",
        cfg.model.name(),
        cfg.requests,
        cfg.workers_per_model,
        cfg.max_batch
    );
    let (unified, conventional) = run_ab(&cfg).expect("serving run");
    print_ab(&unified, &conventional);
}
