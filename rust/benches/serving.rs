//! `cargo bench --bench serving` — end-to-end serving matrix: identical
//! coordinator (router + dynamic batcher + worker pool), backend kernel
//! switched between unified planned (AOT plans + per-worker scratch
//! arenas), unified unplanned (per-call planning — the ablation
//! column), and conventional (baseline).

use ukstc::bench::serving::{print_results, run_matrix, ServingConfig};
use ukstc::models::GanModel;

fn main() {
    let requests = std::env::var("UKSTC_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let model = std::env::var("UKSTC_BENCH_MODEL")
        .ok()
        .and_then(|v| GanModel::from_name(&v))
        .unwrap_or(GanModel::GpGan);
    let batch_workers = std::env::var("UKSTC_BENCH_BATCH_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let cfg = ServingConfig {
        model,
        requests,
        batch_workers,
        ..Default::default()
    };
    eprintln!(
        "serving matrix: model={} requests={} workers={} max_batch={} batch_workers={}",
        cfg.model.name(),
        cfg.requests,
        cfg.workers_per_model,
        cfg.max_batch,
        cfg.batch_workers
    );
    let results = run_matrix(&cfg).expect("serving run");
    print_results(&results);
}
