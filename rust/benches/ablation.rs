//! `cargo bench --bench ablation` — design-choice ablations beyond the
//! paper's tables: formulation (phase vs per-element vs grouped), GEMM
//! routes (§5 discussion), zero-skip baseline honesty check, dilated
//! convolution (§5 future work), and parallel-lane scaling.

use ukstc::bench::{ablation, BenchConfig};

fn main() {
    let iters = std::env::var("UKSTC_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let cfg = BenchConfig {
        iters,
        warmup: 1,
        ..Default::default()
    };
    eprintln!("ablation: iters={} workers={}", cfg.iters, cfg.workers);
    ablation::run_all(&cfg);
}
