//! `cargo bench --bench table4_gans` — regenerates paper Table 4:
//! per-layer transpose-conv ablation for DC-GAN/DiscoGAN, ArtGAN,
//! GP-GAN and EB-GAN, with exact memory-savings bytes.
//!
//! Env overrides: `UKSTC_BENCH_ITERS` (default 2), `UKSTC_BENCH_MODELS`
//! (comma list, default all).

use ukstc::bench::{table4, BenchConfig};
use ukstc::models::GanModel;

fn main() {
    let iters = std::env::var("UKSTC_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let cfg = BenchConfig {
        iters,
        ..Default::default()
    };
    let models: Vec<GanModel> = match std::env::var("UKSTC_BENCH_MODELS") {
        Ok(list) => list
            .split(',')
            .filter_map(GanModel::from_name)
            .collect(),
        Err(_) => GanModel::all().to_vec(),
    };
    eprintln!("table4: iters={} workers={} models={:?}", cfg.iters, cfg.workers, models.iter().map(|m| m.name()).collect::<Vec<_>>());
    let mut ser = Vec::new();
    let mut par = Vec::new();
    for m in models {
        let res = table4::measure_model(m, &cfg);
        table4::print_model(&res);
        ser.push(res.speedup_ser());
        par.push(res.speedup_par());
    }
    println!(
        "\n=== §4.3 summary: mean speedup parallel {:.3}× / serial {:.3}× \
         (paper: ~3× GPU / ~4.2× CPU average) ===",
        ukstc::bench::mean(&par),
        ukstc::bench::mean(&ser)
    );
}
