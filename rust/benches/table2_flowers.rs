//! `cargo bench --bench table2_flowers` — regenerates paper Table 2:
//! the Flower dataset sweep (5 groups × kernels 5/4/3, conventional vs
//! proposed, serial "CPU" + parallel "GPU" lanes, memory savings).
//!
//! Env overrides: `UKSTC_BENCH_SCALE` (default 0.02),
//! `UKSTC_BENCH_ITERS` (default 2), `UKSTC_BENCH_SIZE` (default 224).

use ukstc::bench::{table2, BenchConfig};
use ukstc::workload::datasets::{FLOWER_GROUPS, IMAGE_SIZE};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = BenchConfig {
        scale: env_f64("UKSTC_BENCH_SCALE", 0.02),
        iters: env_usize("UKSTC_BENCH_ITERS", 2),
        ..Default::default()
    };
    let size = env_usize("UKSTC_BENCH_SIZE", IMAGE_SIZE);
    eprintln!(
        "table2: scale={} iters={} workers={} image={size}px (totals extrapolated to full Table 1 counts)",
        cfg.scale, cfg.iters, cfg.workers
    );
    let rows = table2::run_sweep(&FLOWER_GROUPS, &cfg, size);
    table2::print_rows("Table 2 — Flower dataset (conventional vs proposed)", &rows);
}
