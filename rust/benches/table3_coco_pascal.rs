//! `cargo bench --bench table3_coco_pascal` — regenerates paper
//! Table 3: MSCOCO 2017 (10% subset) + PASCAL VOC 2012 sweeps.
//!
//! Same protocol and env overrides as table2_flowers.

use ukstc::bench::{table3, BenchConfig};
use ukstc::workload::datasets::IMAGE_SIZE;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = BenchConfig {
        // The Table 3 datasets are 10-20× larger than the flower groups;
        // a smaller default scale keeps the run comparable.
        scale: env_f64("UKSTC_BENCH_SCALE", 0.004),
        iters: env_usize("UKSTC_BENCH_ITERS", 2),
        ..Default::default()
    };
    let size = env_usize("UKSTC_BENCH_SIZE", IMAGE_SIZE);
    eprintln!(
        "table3: scale={} iters={} workers={} image={size}px",
        cfg.scale, cfg.iters, cfg.workers
    );
    let rows = table3::run_sweep(&cfg, size);
    table3::print_rows(&rows);
}
