//! Quickstart: segregate a kernel, run all three transpose-conv
//! algorithms on one feature map, verify they agree, and print the
//! timing + analytic savings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ukstc::conv::parallel::{run, Algorithm, Lane};
use ukstc::conv::plan::{ConvTransposePlan, Scratch};
use ukstc::conv::segregation::segregate;
use ukstc::conv::{flops, memory, ConvTransposeParams};
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::util::rng::Rng;
use ukstc::util::timing;

fn main() {
    // The paper's Fig. 5/6 setting, scaled up to a realistic feature
    // map: 64×64×32 input, 5×5 kernel, conventional padding P=2.
    let (n_in, n_k, padding, cin, cout) = (64, 5, 2, 32, 16);
    let mut rng = Rng::seeded(42);
    let x = Feature::random(n_in, n_in, cin, &mut rng);
    let k = Kernel::random(n_k, cin, cout, &mut rng);

    println!("== Unified Kernel-Segregated Transpose Convolution — quickstart ==\n");

    // 1. Kernel segregation (Fig. 4).
    let seg = segregate(&k);
    println!("kernel {n_k}×{n_k} segregates into sub-kernels (rows×cols):");
    for (i, sub) in seg.subs.iter().enumerate() {
        println!(
            "  k{}{}: {}×{} ({} taps)",
            i / 2,
            i % 2,
            sub.rows,
            sub.cols,
            sub.taps()
        );
    }

    // 2. All algorithms agree.
    let reference = run(Algorithm::Conventional, Lane::Serial, &x, &k, padding);
    println!(
        "\noutput: {}×{}×{} ({})",
        reference.h,
        reference.w,
        reference.c,
        if reference.h % 2 == 1 { "odd — the case the paper fixes" } else { "even" }
    );
    for alg in Algorithm::all() {
        let out = run(alg, Lane::Serial, &x, &k, padding);
        let err = ops::max_abs_diff(&reference, &out);
        println!("  {:22} max |Δ| vs conventional = {err:.2e}", alg.name());
        assert!(err < 1e-3);
    }

    // 3. Timing comparison.
    println!("\ntimings (serial lane):");
    for alg in [
        Algorithm::Conventional,
        Algorithm::Grouped,
        Algorithm::UnifiedPerElement,
        Algorithm::Unified,
    ] {
        let m = timing::measure(1, 5, || run(alg, Lane::Serial, &x, &k, padding));
        println!(
            "  {:22} {}",
            alg.name(),
            timing::fmt_duration(m.median())
        );
    }

    // 4. Plan/execute: the deployment path.  Build the plan once
    // (segregation + phase geometry + exact scratch sizing), then run
    // through a warm arena — zero allocations per call.
    let p = ConvTransposeParams::new(n_in, n_k, padding, cin, cout);
    let plan = ConvTransposePlan::new(p, &k);
    let mut scratch = Scratch::for_plan(&plan);
    let mut y = plan.new_output();
    plan.run(&x, &mut scratch, &mut y);
    assert_eq!(y, run(Algorithm::Unified, Lane::Serial, &x, &k, padding));
    let m_plan = timing::measure(1, 5, || plan.run(&x, &mut scratch, &mut y));
    let m_oneshot = timing::measure(1, 5, || {
        timing::consume(ukstc::conv::unified::transpose_conv(&x, &k, padding))
    });
    println!(
        "\nplan/execute ({} B scratch, bit-identical): planned {} vs one-shot {} ({:.2}×)",
        plan.scratch_bytes(),
        timing::fmt_duration(m_plan.median()),
        timing::fmt_duration(m_oneshot.median()),
        m_oneshot.median() / m_plan.median()
    );

    // 5. Analytic models (the paper's exact savings columns).
    println!("\nanalytic models:");
    println!(
        "  MACs: conventional {} vs unified {}  (reduction {:.2}×)",
        flops::conventional(&p),
        flops::unified(&p),
        flops::reduction_ratio(&p)
    );
    println!(
        "  memory: upsampled buffer {} B eliminated (Table 4 definition); \
         net savings {} B (Table 2 definition)",
        memory::savings_table4(&p),
        memory::savings_table2(&p)
    );
    println!("\nquickstart OK");
}
