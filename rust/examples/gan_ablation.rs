//! GAN ablation walk-through (the paper's §4.3 protocol on one model):
//! per-layer conventional vs unified timings, FLOP ratios, and the
//! exact memory savings — then a full latent→image generation.
//!
//! ```bash
//! cargo run --release --example gan_ablation [dcgan|artgan|gpgan|ebgan]
//! ```

use ukstc::bench::{table4, BenchConfig};
use ukstc::conv::parallel::{Algorithm, Lane};
use ukstc::models::{GanModel, Generator};
use ukstc::util::rng::Rng;
use ukstc::util::timing;

fn main() {
    let model = std::env::args()
        .nth(1)
        .and_then(|n| GanModel::from_name(&n))
        .unwrap_or(GanModel::DcGan);
    println!("== Table 4 ablation: {} ==", model.name());

    // Per-layer measurement with the shared harness.
    let cfg = BenchConfig {
        iters: 3,
        warmup: 1,
        ..Default::default()
    };
    let result = table4::measure_model(model, &cfg);
    table4::print_model(&result);

    // Full generator pass: latent → image through the unified kernel.
    println!("\nfull generator forward (latent → image):");
    let mut rng = Rng::seeded(7);
    let generator = Generator::random(model, &mut rng);
    let z: Vec<f32> = (0..model.z_dim()).map(|_| rng.normal_f32()).collect();
    for (alg, label) in [
        (Algorithm::Conventional, "conventional"),
        (Algorithm::Unified, "unified"),
    ] {
        let (dt, img) = timing::time_once(|| generator.forward(&z, alg, Lane::Serial));
        println!(
            "  {label:13} {} → image {}×{}×{} (range [{:.3}, {:.3}])",
            timing::fmt_duration(dt),
            img.h,
            img.w,
            img.c,
            img.data.iter().cloned().fold(f32::INFINITY, f32::min),
            img.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        );
    }
    println!("\ngan_ablation OK");
}
