//! §Perf hot-path probe (EXPERIMENTS.md §Perf): times the shared
//! correlation primitive on the three shapes that dominate the paper's
//! workloads — the Table 2/3 dataset shape (224²×3→×1), a GAN head
//! layer (8²×256→16²×128) and a GAN tail layer (64²×128→128²×64).
//!
//! ```bash
//! cargo run --release --example perf_probe
//! ```

use ukstc::conv::parallel::{run, Algorithm, Lane};
use ukstc::tensor::{Feature, Kernel};
use ukstc::util::rng::Rng;
use ukstc::util::timing;

fn main() {
    let mut rng = Rng::seeded(1);
    // Case A: Table 2 shape (224px, k5, P2, cin3, cout1)
    let xa = Feature::random(224, 224, 3, &mut rng);
    let ka = Kernel::random(5, 3, 1, &mut rng);
    // Case B: GAN layer (8x8x256 -> 16x16x128)
    let xb = Feature::random(8, 8, 256, &mut rng);
    let kb = Kernel::random(4, 256, 128, &mut rng);
    // Case C: late GAN layer (64x64x128 -> 128x128x64)
    let xc = Feature::random(64, 64, 128, &mut rng);
    let kc = Kernel::random(4, 128, 64, &mut rng);
    for (name, x, k) in [("A:224px/c3->1", &xa, &ka), ("B:8px/c256->128", &xb, &kb), ("C:64px/c128->64", &xc, &kc)] {
        for alg in [Algorithm::Conventional, Algorithm::Unified] {
            let m = timing::measure(2, 7, || run(alg, Lane::Serial, x, k, 2));
            println!("{name} {:<14} {}", alg.name(), timing::fmt_duration(m.best()));
        }
    }
}
