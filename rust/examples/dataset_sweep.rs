//! Table 2/3-style dataset sweep with CLI-selectable geometry — the
//! knob-turning companion to the fixed paper benches.
//!
//! ```bash
//! cargo run --release --example dataset_sweep -- [image_size] [scale]
//! # e.g. a fast 64px sweep over 5% of each flower group:
//! cargo run --release --example dataset_sweep -- 64 0.05
//! ```

use ukstc::bench::{table2, BenchConfig};
use ukstc::workload::datasets::{FLOWER_GROUPS, TABLE3_GROUPS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let image_size: usize = args
        .first()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let scale: f64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(0.02);
    let cfg = BenchConfig {
        scale,
        iters: 2,
        warmup: 1,
        ..Default::default()
    };
    println!("dataset sweep: image={image_size}px scale={scale} workers={}", cfg.workers);

    let rows = table2::run_sweep(&FLOWER_GROUPS, &cfg, image_size);
    table2::print_rows(
        &format!("Flower dataset @ {image_size}px (conventional vs proposed)"),
        &rows,
    );

    let rows3 = table2::run_sweep(&TABLE3_GROUPS, &cfg, image_size);
    table2::print_rows(
        &format!("MSCOCO + PASCAL @ {image_size}px (conventional vs proposed)"),
        &rows3,
    );
    println!("\ndataset_sweep OK");
}
