//! Real generator training steps over the planned backward lanes
//! (DESIGN.md §Backward-Execution): forward trace → MSE loss → planned
//! data-grad + weight-grad per layer → SGD update, on a full Table-4
//! GAN generator.  Exits nonzero unless the loss strictly decreases —
//! CI runs this as the training gate.
//!
//! ```bash
//! cargo run --release --example training_step -- [--steps N] [--lr F] [--gemm]
//! ```

use ukstc::models::{GanModel, Generator, TrainStep};
use ukstc::tune::ExecStrategy;
use ukstc::util::rng::Rng;
use ukstc::util::timing;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut steps = 3usize;
    let mut lr = 0.05f32;
    let mut gemm = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--steps" => {
                i += 1;
                steps = args[i].parse().expect("--steps wants a number");
            }
            "--lr" => {
                i += 1;
                lr = args[i].parse().expect("--lr wants a number");
            }
            "--gemm" => gemm = true,
            other => panic!("unknown argument: {other}"),
        }
        i += 1;
    }

    let model = GanModel::smallest();
    let mut rng = Rng::seeded(0x7EA1);
    let mut gen = Generator::random(model, &mut rng);
    if gemm {
        // Pin the phase-GEMM backward data-grad lane on every layer —
        // what `ukstc tune --backward` would pick on GEMM-friendly
        // shapes.
        let pins: Vec<ExecStrategy> =
            gen.layers.iter().map(|_| ExecStrategy::serial_gemm()).collect();
        gen.set_backward_strategies(&pins);
    }
    println!(
        "== {} training: {} layers, {} weight floats, lr {lr}, {} backward ==\n",
        model.name(),
        gen.layers.len(),
        gen.weight_bytes() / 4,
        if gemm { "phase-GEMM" } else { "direct" }
    );

    let mut ts = TrainStep::new(gen, &mut rng, lr);
    let mut prev = f32::INFINITY;
    for step in 1..=steps {
        let (t, loss) = timing::time_once(|| ts.step());
        println!(
            "step {step}: loss {loss:.6} ({})",
            timing::fmt_duration(t)
        );
        assert!(
            loss < prev,
            "loss must strictly decrease (step {step}: {loss} >= {prev})"
        );
        prev = loss;
    }
    let final_loss = ts.loss();
    assert!(final_loss < prev, "post-update loss must beat the last step");
    println!("\ntraining_step OK (final loss {final_loss:.6}, strictly decreasing)");
}
