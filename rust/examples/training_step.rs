//! Training-stage demo (§2's claim that the optimization helps "in the
//! training and inference stages"): run forward + backward through a
//! transpose-conv layer with both gradient routes, verify they agree,
//! and take an SGD step that provably reduces the loss.
//!
//! ```bash
//! cargo run --release --example training_step
//! ```

use ukstc::conv::backward::{
    grad_input_conventional, grad_input_unified, grad_kernel_conventional, grad_kernel_unified,
};
use ukstc::conv::{conventional, unified};
use ukstc::tensor::{ops, Feature, Kernel};
use ukstc::util::rng::Rng;
use ukstc::util::timing;

fn loss(y: &Feature, target: &Feature) -> f32 {
    y.data
        .iter()
        .zip(&target.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        / y.data.len() as f32
}

fn main() {
    let (n_in, n_k, padding, cin, cout) = (16, 4, 2, 8, 4);
    let mut rng = Rng::seeded(11);
    let x = Feature::random(n_in, n_in, cin, &mut rng);
    let mut k = Kernel::random(n_k, cin, cout, &mut rng);
    for v in &mut k.data {
        *v *= 0.25;
    }
    let target = Feature::random(2 * n_in, 2 * n_in, cout, &mut rng);

    println!("== training step through the unified transpose conv ==\n");
    let y0 = unified::transpose_conv(&x, &k, padding);
    let l0 = loss(&y0, &target);
    println!("initial loss: {l0:.6}");

    // dL/dy for MSE.
    let mut dy = y0.clone();
    for (d, t) in dy.data.iter_mut().zip(&target.data) {
        *d = 2.0 * (*d - t) / (y0.data.len() as f32);
    }

    // Both gradient routes agree (and the unified one never builds the
    // upsampled buffer).
    let (t_conv, dk_conv) =
        timing::time_once(|| grad_kernel_conventional(&x, &dy, n_k, padding));
    let (t_uni, dk_uni) = timing::time_once(|| grad_kernel_unified(&x, &dy, n_k, padding));
    let dk_err = dk_conv
        .data
        .iter()
        .zip(&dk_uni.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\ngrad_kernel: conventional {} vs unified {} (max |Δ| = {dk_err:.2e})",
        timing::fmt_duration(t_conv), timing::fmt_duration(t_uni));
    assert!(dk_err < 1e-4);

    let (ti_conv, dx_conv) =
        timing::time_once(|| grad_input_conventional(&dy, &k, n_in, padding));
    let (ti_uni, dx_uni) = timing::time_once(|| grad_input_unified(&dy, &k, n_in, padding));
    let dx_err = ops::max_abs_diff(&dx_conv, &dx_uni);
    println!("grad_input:  conventional {} vs unified {} (max |Δ| = {dx_err:.2e})",
        timing::fmt_duration(ti_conv), timing::fmt_duration(ti_uni));
    assert!(dx_err < 1e-4);

    // SGD steps on the kernel must reduce the loss monotonically-ish.
    let lr = 2.0;
    let mut prev = l0;
    for step in 1..=5 {
        let y = unified::transpose_conv(&x, &k, padding);
        let mut dy = y.clone();
        for (d, t) in dy.data.iter_mut().zip(&target.data) {
            *d = 2.0 * (*d - t) / (y.data.len() as f32);
        }
        let dk = grad_kernel_unified(&x, &dy, n_k, padding);
        for (w, g) in k.data.iter_mut().zip(&dk.data) {
            *w -= lr * g;
        }
        let l = loss(&unified::transpose_conv(&x, &k, padding), &target);
        println!("step {step}: loss {l:.6}");
        assert!(l < prev, "loss must decrease");
        prev = l;
    }

    // Cross-check forward against the conventional algorithm after
    // training (weights changed, equality must still hold).
    let a = unified::transpose_conv(&x, &k, padding);
    let b = conventional::transpose_conv(&x, &k, padding);
    assert!(ops::max_abs_diff(&a, &b) < 1e-4);
    println!("\ntraining_step OK (loss {l0:.4} → {prev:.4}, both routes agree)");
}
