//! **End-to-end driver** (the EXPERIMENTS.md validation run): start the
//! serving coordinator, load the AOT-compiled DC-GAN generator through
//! PJRT (JAX/Pallas → HLO text → PJRT CPU — no Python at runtime),
//! replay a Poisson request trace, and report latency/throughput.
//!
//! Falls back to the native Rust backend with `--rust` or when the
//! artifacts are missing.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve
//! cargo run --release --example serve -- --rust      # native backend
//! ```

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use ukstc::conv::parallel::{Algorithm, Lane};
use ukstc::coordinator::backend::{Backend, RustBackend};
use ukstc::coordinator::batcher::BatchPolicy;
use ukstc::coordinator::Coordinator;
use ukstc::models::GanModel;
use ukstc::runtime::{Engine, PjrtBackend};
use ukstc::util::rng::Rng;
use ukstc::workload::generator::poisson_trace;

fn main() -> anyhow::Result<()> {
    ukstc::util::logging::init();
    let use_rust = std::env::args().any(|a| a == "--rust");
    let artifacts = Path::new("artifacts");

    let backend: Arc<dyn Backend> = if !use_rust && artifacts.join("manifest.json").exists() {
        println!("backend: PJRT (AOT Pallas artifact dcgan_b8)");
        let mut engine = Engine::new(artifacts)?;
        engine.compile("dcgan_b8")?;
        Arc::new(PjrtBackend::new(Arc::new(engine), "dcgan_b8", 7)?)
    } else {
        println!("backend: native Rust unified kernels (dcgan)");
        Arc::new(RustBackend::new(
            GanModel::DcGan,
            Algorithm::Unified,
            Lane::Serial,
            7,
            8,
        ))
    };
    let z_dim = backend.z_dim();
    let model = backend.model_name().to_string();

    let coord = Coordinator::builder()
        .queue_capacity(256)
        .workers_per_model(2)
        .batch_policy(BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(4),
        })
        .register(backend)
        .start()?;

    // Open-loop Poisson trace: 80 requests at 25 req/s.
    let (rate, n) = (15.0, 80);
    println!("replaying {n} Poisson requests at {rate} req/s against '{model}'...");
    let mut rng = Rng::seeded(2026);
    let trace = poisson_trace(&model, z_dim, rate, n, &mut rng);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for tr in trace {
        let now = t0.elapsed().as_secs_f64();
        if tr.at > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(tr.at - now));
        }
        match coord.submit(tr.request) {
            Ok(rx) => pending.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    let mut latencies: Vec<f64> = Vec::new();
    let mut first_image_stats = None;
    for rx in pending {
        let resp = rx.recv()?;
        latencies.push(resp.total_s());
        first_image_stats.get_or_insert((resp.image.h, resp.image.w, resp.image.c));
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = coord.metrics(&model).unwrap();
    let (h, w, c) = first_image_stats.unwrap();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize] * 1e3;
    println!("\n=== serve results ===");
    println!("images generated : {} ({h}×{w}×{c})", snap.completed);
    println!("wall time        : {wall:.2} s");
    println!("throughput       : {:.2} img/s", snap.completed as f64 / wall);
    println!(
        "latency          : p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms",
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    println!(
        "batch size       : mean {:.2}  p50 {:.0}  p95 {:.0}",
        snap.mean_batch_size, snap.batch_p50, snap.batch_p95
    );
    println!("rejected         : {}", snap.rejected);
    println!("\nserve OK");
    Ok(())
}
